"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """q: (B,H,Sq,d), k/v: (B,K,Skv,d) with H % K == 0. f32 softmax."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    g = H // K
    qg = q.reshape(B, K, g, Sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), Skv - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, d).astype(q.dtype)


def categorical_logprob_ref(logits, tokens) -> jax.Array:
    """logits: (..., V) f32/bf16; tokens: (...) int32. Returns (...) f32:
    log_softmax(logits)[token] — the LM observe-site hot spot."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tok = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    return tok - lse


def ssd_scan_ref(x, dt, A, B, C, *, chunk: int) -> jax.Array:
    """Mamba-2 SSD (see models/ssm.ssd_reference; re-exported here so kernel
    tests depend only on kernels.ref)."""
    from ..models.ssm import ssd_reference

    return ssd_reference(x, dt, A, B, C, chunk)


def semiring_matmul_ref(a, b, *, semiring: str = "logsumexp") -> jax.Array:
    """Log-space semiring matmul: out[..., i, j] = ⊕_k a[..., i, k] + b[..., k, j]
    with ⊕ = logsumexp (sum-product) or max (max-product). Batch dims broadcast.

    The sum-product form uses the shifted-exponential identity
    ``logsumexp_k(a+b) = am + bm + log(exp(a-am) @ exp(b-bm))`` so the inner
    loop is a real matmul instead of a materialized (..., M, K, N) broadcast —
    algebraically identical, and the shift keeps it overflow-safe (this is the
    same rewrite the Pallas kernel uses per tile). Max-plus has no matmul
    identity and keeps the broadcast form.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if semiring == "max":
        return jnp.max(a[..., :, :, None] + b[..., None, :, :], axis=-2)
    if semiring != "logsumexp":
        raise ValueError(f"unknown semiring {semiring!r}")
    am = jnp.max(a, axis=-1, keepdims=True)  # (..., M, 1)
    bm = jnp.max(b, axis=-2, keepdims=True)  # (..., 1, N)
    am_s = jnp.where(jnp.isfinite(am), am, 0.0)  # fully -inf rows stay -inf, not nan
    bm_s = jnp.where(jnp.isfinite(bm), bm, 0.0)
    p = jnp.einsum("...mk,...kn->...mn", jnp.exp(a - am_s), jnp.exp(b - bm_s))
    return jnp.log(p) + am_s + bm_s


def leapfrog_ref(z, r, inv_mass, step_size, num_steps, potential_fn, *, max_steps):
    """Batched leapfrog oracle for `ops.leapfrog`, in the textbook
    two-half-kicks-per-step form (deliberately *not* the fused kernel's
    shared-gradient rewrite, so parity tests compare independent algebra).

    z, r, inv_mass: (C, D); step_size: (C,) (sign = integration direction);
    num_steps: (C,) int (0 = chain frozen, position/momentum pass through).
    Runs `min(max(num_steps), max_steps)` masked iterations; returns
    (z', r', potential(z')).
    """
    vg = jax.vmap(jax.value_and_grad(potential_fn))
    eps = step_size[:, None].astype(jnp.float32)
    n = num_steps[:, None].astype(jnp.int32)
    nmax = jnp.minimum(jnp.max(n), max_steps)

    def cond(carry):
        return carry[0] < nmax

    def body(carry):
        i, z, r = carry
        active = i < n  # (C, 1)
        _, g = vg(z)
        r2 = r - 0.5 * eps * g
        z2 = z + eps * inv_mass * r2
        _, g2 = vg(z2)
        r2 = r2 - 0.5 * eps * g2
        z = jnp.where(active, z2, z)
        r = jnp.where(active, r2, r)
        return (i + 1, z, r)

    _, z, r = jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32), z, r))
    pe, _ = vg(z)
    return z, r, pe


_LOG_2PI = 1.8378770664093453


def _bt(x) -> jax.Array:
    """Batched matrix transpose (swap the trailing two axes)."""
    return jnp.swapaxes(x, -1, -2)


def gaussian_combine_ref(f, g):
    """Associative Kalman combine of two information-form Gaussian edge factors.

    An *edge factor* F(a, b) over a left variable a (width d1) and a right
    variable b (width d2) is the 6-tuple ``(J11, J12, J22, h1, h2, c)``
    encoding

        log F(a, b) = -1/2 [a;b]^T [[J11, J12],[J12^T, J22]] [a;b]
                      + [h1;h2]^T [a;b] + c

    with ``J11: (..., d1, d1)``, ``J12: (..., d1, d2)``, ``J22: (..., d2, d2)``,
    ``h1: (..., d1)``, ``h2: (..., d2)``, ``c: (...)``. Batch dims broadcast.

    The combine integrates out the shared middle variable of F(a, b) · G(b, c):

        (F ⊗ G)(a, c) = ∫ F(a, b) G(b, c) db

    which is exact for Gaussians (Schur complement of the middle block):
    with ``M = F.J22 + G.J11`` and ``hb = F.h2 + G.h1``,

        J11' = F.J11 - F.J12 M⁻¹ F.J12^T
        J12' = -F.J12 M⁻¹ G.J12
        J22' = G.J22 - G.J12^T M⁻¹ G.J12
        h1'  = F.h1 - F.J12 M⁻¹ hb
        h2'  = G.h2 - G.J12^T M⁻¹ hb
        c'   = F.c + G.c + 1/2 hb^T M⁻¹ hb - 1/2 log|M| + (d_b/2) log 2π

    This operator is associative (it is marginalization of a chain graph, and
    integration order over interior variables is exchangeable), which is what
    legalizes the O(log T) tree in `ops.gaussian_scan`. M must be positive
    definite — guaranteed when each factor's diagonal blocks came from genuine
    conditional densities (see kernels/gaussian.py for the conditioning
    contract).
    """
    fJ11, fJ12, fJ22, fh1, fh2, fc = (jnp.asarray(x, jnp.float32) for x in f)
    gJ11, gJ12, gJ22, gh1, gh2, gc = (jnp.asarray(x, jnp.float32) for x in g)
    M = fJ22 + gJ11
    hb = fh2 + gh1
    db = M.shape[-1]
    # broadcast batch dims once so jnp.linalg.solve sees matching operands
    batch = jnp.broadcast_shapes(
        fJ11.shape[:-2], fJ12.shape[:-2], gJ12.shape[:-2], gJ22.shape[:-2],
        M.shape[:-2], hb.shape[:-1], jnp.shape(fc), jnp.shape(gc),
    )
    M = jnp.broadcast_to(M, batch + M.shape[-2:])
    fJ12b = jnp.broadcast_to(fJ12, batch + fJ12.shape[-2:])
    gJ12b = jnp.broadcast_to(gJ12, batch + gJ12.shape[-2:])
    hbb = jnp.broadcast_to(hb, batch + hb.shape[-1:])
    MiFt = jnp.linalg.solve(M, _bt(fJ12b))          # (..., db, d1)
    MiG = jnp.linalg.solve(M, gJ12b)                # (..., db, d2)
    Mih = jnp.linalg.solve(M, hbb[..., None])[..., 0]
    J11 = fJ11 - fJ12 @ MiFt
    J12 = -(fJ12 @ MiG)
    J22 = gJ22 - _bt(gJ12b) @ MiG
    h1 = fh1 - (fJ12b @ Mih[..., None])[..., 0]
    h2 = gh2 - (_bt(gJ12b) @ Mih[..., None])[..., 0]
    _, logdet = jnp.linalg.slogdet(M)
    c = (
        fc + gc + 0.5 * jnp.sum(hbb * Mih, -1)
        - 0.5 * logdet + 0.5 * db * _LOG_2PI
    )
    # Schur complements are symmetric in exact arithmetic; resymmetrize so
    # float error never compounds across a long chain of combines
    J11 = 0.5 * (J11 + _bt(J11))
    J22 = 0.5 * (J22 + _bt(J22))
    return (
        jnp.broadcast_to(J11, batch + J11.shape[-2:]),
        jnp.broadcast_to(J12, batch + J12.shape[-2:]),
        jnp.broadcast_to(J22, batch + J22.shape[-2:]),
        jnp.broadcast_to(h1, batch + h1.shape[-1:]),
        jnp.broadcast_to(h2, batch + h2.shape[-1:]),
        jnp.broadcast_to(c, batch),
    )


def gaussian_scan_ref(factors):
    """Sequential left-fold oracle for `ops.gaussian_scan`: the ordered
    combine F_0 ⊗ F_1 ⊗ ... ⊗ F_{T-1} of a stack of information-form edge
    factors, one `gaussian_combine_ref` at a time (O(T) depth — the allclose
    target for the O(log T) associative-tree path).

    ``factors`` is the edge 6-tuple with a T axis left of each leaf's event
    axes: matrices (..., T, d, d), info vectors (..., T, d), scalar (..., T).
    Returns the single edge factor linking the first left variable to the
    last right variable, every interior variable integrated out.
    """
    J11, J12, J22, h1, h2, c = factors
    T = J11.shape[-3]

    def at(t):
        return (J11[..., t, :, :], J12[..., t, :, :], J22[..., t, :, :],
                h1[..., t, :], h2[..., t, :], c[..., t])

    out = at(0)
    for t in range(1, T):
        out = gaussian_combine_ref(out, at(t))
    return out


def resample_inputs_ref(log_weights) -> jax.Array:
    """Normalized-weight cumsum for systematic resampling, shared by the
    oracle and the kernel dispatch so both backends see bit-identical inputs.

    Degenerate populations (every log-weight ``-inf``, so the normalizer is
    ``-inf`` and self-normalization is 0/0) fall back to uniform weights:
    when every particle is impossible, resampling keeps them all rather than
    propagating NaN through the sweep."""
    lw = jnp.asarray(log_weights, jnp.float32)
    n = lw.shape[-1]
    norm = jax.scipy.special.logsumexp(lw, axis=-1, keepdims=True)
    finite = jnp.isfinite(norm)
    w = jnp.where(
        finite,
        jnp.exp(lw - jnp.where(finite, norm, 0.0)),
        jnp.float32(1.0 / n),
    )
    return jnp.cumsum(w, axis=-1)


def resample_grid_ref(u0, n: int) -> jax.Array:
    """The sorted systematic grid u_i = (u0 + i)/n, u0 ~ U[0, 1)."""
    u0 = jnp.asarray(u0, jnp.float32)
    return (u0 + jnp.arange(n, dtype=jnp.float32)) / n


def systematic_resample_ref(log_weights, u0) -> jax.Array:
    """Systematic-resampling oracle for `ops.resample`: ancestor indices for
    n particles from unnormalized `log_weights` (n,) and one shared uniform
    draw ``u0`` in [0, 1).

    With c the normalized-weight cumsum and u_i = (u0 + i)/n the sorted
    systematic grid, ancestor i is ``#{j : c_j <= u_i}`` — i.e.
    ``searchsorted(c, u, side="right")`` — clipped to n-1 against float
    rounding in the final cumsum entry. Zero-weight particles produce flat
    cumsum runs and are never selected; all-equal weights reproduce the
    identity permutation exactly (u0 < 1 keeps every u_i strictly inside its
    own cumsum cell)."""
    c = resample_inputs_ref(log_weights)
    u = resample_grid_ref(u0, c.shape[-1])
    idx = jnp.searchsorted(c, u, side="right")
    return jnp.minimum(idx, c.shape[-1] - 1).astype(jnp.int32)


def hmm_scan_ref(factors, *, semiring: str = "logsumexp") -> jax.Array:
    """Sequential left-fold oracle for `ops.hmm_scan`: the ordered semiring
    product F_0 ⊗ F_1 ⊗ ... ⊗ F_{T-1} of a (..., T, K, K) stack of log-factors,
    one pairwise `semiring_matmul_ref` at a time (O(T) depth — the allclose
    target for the O(log T) associative-tree path)."""
    out = factors[..., 0, :, :]
    for t in range(1, factors.shape[-3]):
        out = semiring_matmul_ref(out, factors[..., t, :, :], semiring=semiring)
    return out
