"""Fused HMC leapfrog integrator — Pallas TPU kernel, batched over chains.

The MCMC hot loop is the leapfrog integrator: for every chain, every
transition runs `n` steps of

    r -= eps/2 * grad U(z);   z += eps * M^-1 r;   r -= eps/2 * grad U(z)

The generic path (PR 2) vmapped a per-chain `lax.scan` whose body called
`jax.grad` twice per step and — because `lax.cond` under `vmap` lowers to
`select` — burned `max_num_steps` gradient evaluations per transition no
matter how short the trajectory actually was. This kernel replaces that with
one fused program per *block of chains*:

* the whole trajectory runs inside the kernel: positions, momenta and
  gradients stay in VMEM across steps — zero HBM round-trips between
  leapfrog sub-steps (the flash-attention locality argument applied to the
  sampler);
* the classic "store the gradient" rewrite shares one gradient evaluation
  between the trailing half-kick of step `i` and the leading half-kick of
  step `i+1`, so a trajectory of `n` steps costs `n + 1` gradient
  evaluations instead of `2 n`;
* steps run under a `lax.while_loop` bounded by the *largest live*
  `num_steps` in the block, with per-chain active masks — chains with short
  (or zero: NUTS's frozen chains) trajectories stop paying as soon as every
  chain in their block is done.

The potential is model-specific, so it cannot be baked into the kernel
source: callers trace `jax.value_and_grad(potential_fn)` to a jaxpr *once*
(see `ops.trace_potential`), and the jaxpr's captured constants — model
data, transform parameters — enter the kernel as ordinary Pallas inputs
(Pallas rejects captured constants by design). The kernel body replays the
jaxpr with `jax.core.eval_jaxpr` on VMEM-resident values, `vmap`-ed over the
chain rows of the block.

No `custom_vjp`: MCMC never differentiates through its own transition (the
Metropolis accept is not differentiable anyway), so unlike `semiring.py`
this kernel carries no AD rule — `jax.grad` through `ops.leapfrog` raises,
which is the correct loud failure.

The pure-jnp oracle is `ref.leapfrog_ref`, deliberately written in the
textbook two-half-kicks-per-step form rather than sharing this module's
shared-gradient rewrite — the two are algebraically identical, so the
fused-vs-reference parity test (conformance suite) checks real math, not
just that one function was called twice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def shared_grad_leapfrog(z, r, inv_mass, eps, num_steps, max_steps, vg_fn):
    """The masked shared-gradient leapfrog the kernel body runs.

    z, r, inv_mass: (c, D); eps, num_steps: (c, 1); vg_fn: (c, D) ->
    ((c,) potential, (c, D) gradient). Runs `min(max(num_steps), max_steps)`
    iterations of the one-gradient-per-step form with per-chain active
    masks; returns (z', r', potential(z')).
    """
    live = num_steps > 0  # (c, 1)
    nmax = jnp.minimum(jnp.max(num_steps), max_steps)
    _, g0 = vg_fn(z)
    # leading half-kick (only chains that take at least one step)
    r = jnp.where(live, r - 0.5 * eps * g0, r)

    def cond(carry):
        return carry[0] < nmax

    def body(carry):
        i, z, r, g = carry
        active = i < num_steps  # (c, 1)
        z2 = z + eps * inv_mass * r
        _, g2 = vg_fn(z2)
        r2 = r - eps * g2  # full kick; the overshoot is repaid below
        z = jnp.where(active, z2, z)
        r = jnp.where(active, r2, r)
        g = jnp.where(active, g2, g)
        return (i + 1, z, r, g)

    init = (jnp.zeros((), jnp.int32), z, r, g0)
    _, z, r, g = jax.lax.while_loop(cond, body, init)
    # repay half of the final full kick -> trailing half-kick
    r = jnp.where(live, r + 0.5 * eps * g, r)
    pe, _ = vg_fn(z)
    return z, r, pe


def _leapfrog_kernel(
    z_ref, r_ref, minv_ref, eps_ref, n_ref, *rest, jaxpr, const_shapes, max_steps
):
    nconsts = len(const_shapes)
    const_refs = rest[:nconsts]
    zo_ref, ro_ref, pe_ref = rest[nconsts:]
    consts = [
        c[...].reshape(shape) for c, shape in zip(const_refs, const_shapes)
    ]

    def vg_fn(z_block):
        def one(zvec):
            pe, g = jax.core.eval_jaxpr(jaxpr, consts, zvec)
            return pe, g

        return jax.vmap(one)(z_block)

    z, r, pe = shared_grad_leapfrog(
        z_ref[...], r_ref[...], minv_ref[...], eps_ref[...], n_ref[...],
        max_steps, vg_fn,
    )
    zo_ref[...] = z
    ro_ref[...] = r
    pe_ref[...] = pe[:, None]


def leapfrog_fused(
    z: jax.Array,          # (C, D) positions, f32
    r: jax.Array,          # (C, D) momenta, f32
    inv_mass: jax.Array,   # (C, D) diagonal inverse mass
    step_size: jax.Array,  # (C,) per-chain step size (sign = direction)
    num_steps: jax.Array,  # (C,) int32 per-chain step counts (0 = frozen)
    consts,                # jaxpr constants (model data etc.), kernel inputs
    *,
    jaxpr,                 # jaxpr of value_and_grad(potential_fn) on (D,)
    max_steps: int,
    block_chains: int = 8,
    interpret: bool = False,
):
    """Fused leapfrog over a (C, D) block of chains; returns (z', r', pe').

    `kernels/ops.leapfrog` is the public entry point — it resolves the
    backend, traces the potential, and pads C to the block size. Chains are
    edge-padded (repeating the last live row) so padded rows evaluate the
    potential at an in-support point instead of an arbitrary zero vector.
    """
    C, D = z.shape
    bc = min(block_chains, C)
    Cp = -(-C // bc) * bc
    if Cp != C:
        pad = ((0, Cp - C), (0, 0))
        z = jnp.pad(z, pad, mode="edge")
        r = jnp.pad(r, pad, mode="edge")
        inv_mass = jnp.pad(inv_mass, pad, mode="edge")
        step_size = jnp.pad(step_size, ((0, Cp - C),), mode="edge")
        # padded chains take zero steps: they only pay the final pe eval
        num_steps = jnp.pad(num_steps, ((0, Cp - C),))
    consts = [jnp.asarray(c) for c in consts]
    const_shapes = tuple(jnp.shape(c) for c in consts)
    # scalars ride as (1, 1) blocks; everything else keeps its shape
    const_in = [c.reshape((1, 1)) if c.ndim == 0 else c for c in consts]
    grid = (Cp // bc,)

    def _cspec(c):
        return pl.BlockSpec(c.shape, lambda i, nd=c.ndim: (0,) * nd)

    out = pl.pallas_call(
        functools.partial(
            _leapfrog_kernel,
            jaxpr=jaxpr,
            const_shapes=const_shapes,
            max_steps=max_steps,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, D), lambda i: (i, 0)),  # z
            pl.BlockSpec((bc, D), lambda i: (i, 0)),  # r
            pl.BlockSpec((bc, D), lambda i: (i, 0)),  # inv_mass
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),  # eps
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),  # num_steps
        ]
        + [_cspec(c) for c in const_in],
        out_specs=[
            pl.BlockSpec((bc, D), lambda i: (i, 0)),
            pl.BlockSpec((bc, D), lambda i: (i, 0)),
            pl.BlockSpec((bc, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Cp, D), jnp.float32),
            jax.ShapeDtypeStruct((Cp, D), jnp.float32),
            jax.ShapeDtypeStruct((Cp, 1), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(
        z,
        r,
        inv_mass,
        step_size[:, None].astype(jnp.float32),
        num_steps[:, None].astype(jnp.int32),
        *const_in,
    )
    z_new, r_new, pe = out
    return z_new[:C], r_new[:C], pe[:C, 0]
