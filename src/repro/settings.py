"""One typed surface for every ``REPRO_*`` environment knob.

The knobs accumulated across the kernel, contraction-planner, MCMC,
compilation-cache, serving, and bench layers used to be raw
``os.environ.get`` calls scattered over half a dozen modules, each with its
own default literal and truthiness convention. This module is the single
registry: every knob is declared once (name, default, type, one-line
effect), every library read goes through a typed getter here, and the
environment-variable table in ``docs/backends.md`` is *checked against*
this registry (`render_env_table`; the docs page doctests the comparison,
so the table cannot drift from the code).

Semantics, unchanged from the scattered reads this replaces:

* the environment always wins — getters read ``os.environ`` at **call
  time**, never at import time, so tests and launchers may flip a knob
  mid-process;
* boolean knobs treat ``0`` / ``false`` / ``off`` (case-insensitive) as
  false and anything else as true;
* unknown knob names raise ``KeyError`` immediately — a typo'd getter is a
  bug, not a silent default.

Example::

    >>> from repro import settings
    >>> settings.get_bool("REPRO_MCMC_FUSED")     # default "1" -> True
    True
    >>> import os; os.environ["REPRO_MCMC_FUSED"] = "off"
    >>> settings.get_bool("REPRO_MCMC_FUSED")     # env wins, read at call time
    False
    >>> del os.environ["REPRO_MCMC_FUSED"]
    >>> settings.get_int("REPRO_ENUM_PLAN_BB")
    10
    >>> settings.get_raw("REPRO_TYPO")
    Traceback (most recent call last):
        ...
    KeyError: "unknown settings knob 'REPRO_TYPO' (see repro.settings.KNOBS)"
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

_FALSE = ("0", "false", "off")


@dataclass(frozen=True)
class Knob:
    """One documented environment knob."""

    name: str
    default: Optional[str]  # None = unset by default
    kind: str  # "str" | "bool" | "int" | "float" | "path"
    effect: str  # one-line description (the docs table's "effect" column)
    choices: Optional[Tuple[str, ...]] = None
    deprecated: bool = False

    @property
    def default_display(self) -> str:
        return "unset" if self.default is None else f"`{self.default}`"


# ---------------------------------------------------------------------------
# the registry — one row per knob, in docs-table order
# ---------------------------------------------------------------------------

_KNOB_ROWS = [
    Knob("REPRO_KERNEL_BACKEND", "auto", "str",
         "kernel backend: `tpu`, `interpret`, `reference`/`ref`, or `auto` "
         "(platform default)",
         choices=("tpu", "interpret", "reference", "ref", "auto")),
    Knob("REPRO_PALLAS_INTERPRET", None, "str",
         "**deprecated** interpret-mode flag; consulting it warns "
         "(migration above)", deprecated=True),
    Knob("REPRO_MCMC_FUSED", "1", "bool",
         "`0`/`false`/`off` routes `MCMC.run` through the legacy per-chain "
         "vmap sampler instead of the fused batched driver (`ops.leapfrog` + "
         "cross-chain adaptation); per-instance override via "
         "`MCMC(..., fused=...)`"),
    Knob("REPRO_ENUM_DISPATCH", "auto", "str",
         "`auto` routes eliminations through the contraction planner; "
         "`pairwise` forces the greedy eliminator (bit-identical pre-planner "
         "path; for the Gaussian semiring, the dense sequential Schur "
         "reference)", choices=("auto", "pairwise")),
    Knob("REPRO_ENUM_CHAIN_MIN", None, "int",
         "overrides the planner's ~18-edge chain crossover; when set, chains "
         "also keep the legacy `hmm_scan` tree lowering"),
    Knob("REPRO_ENUM_CHAIN_LOWER", "auto", "str",
         "pins the chain lowering: `scan` (plan-level `lax.scan`), `tree` "
         "(`hmm_scan`; `gaussian_scan` for Gaussian chains), or `folds` "
         "(sequential `semiring_matmul` / `gaussian_combine`)",
         choices=("auto", "scan", "tree", "folds")),
    Knob("REPRO_ENUM_PLAN_BB", "10", "int",
         "max dim count for branch-and-bound elimination ordering; larger "
         "problems fall back to greedy min-cost"),
    Knob("REPRO_ENUM_PLAN_CACHE", "1", "bool",
         "`0`/`false`/`off` disables the structural plan cache (every "
         "elimination replans)"),
    Knob("REPRO_ENUM_PLAN_CACHE_SIZE", "256", "int",
         "plan-cache capacity (FIFO eviction)"),
    Knob("REPRO_COMPILATION_CACHE_DIR", "~/.cache/repro/xla-cache", "path",
         "persistent XLA compilation-cache dir used by `launch/serve.py`, "
         "`launch/train.py`, `launch/stream.py`, and the bench stage; "
         "`0`/`off`/`none` disables"),
    Knob("REPRO_COMPILATION_CACHE_MIN_COMPILE_S", "0.5", "float",
         "only compilations slower than this persist to the cache"),
    Knob("REPRO_SMC_RESAMPLE", "systematic", "str",
         "default SMC resampling scheme: `systematic` (the `ops.resample` "
         "sorted-uniform kernel, one shared uniform per event) or "
         "`multinomial` (`jax.random.categorical`, N independent draws — "
         "higher variance, kept for A/B checks)",
         choices=("systematic", "multinomial")),
    Knob("REPRO_SERVE_DEADLINE_MS", None, "float",
         "default per-request deadline for the HTTP serving front end "
         "(`serve/server.py`); requests whose projected queue wait exceeds "
         "it are shed with HTTP 429. Unset = no default deadline"),
    Knob("REPRO_BENCH_TOLERANCE", "0.25", "float",
         "bench-gate relative tolerance on steady-state metrics"),
    Knob("REPRO_BENCH_ABS_MS", "0.5", "float",
         "bench-gate absolute slack on `*_ms` metrics"),
    Knob("REPRO_BENCH_ABS_RATE", "0.05", "float",
         "bench-gate absolute slack on rate metrics (`shed_rate`)"),
    Knob("REPRO_BENCH_COLD_TOLERANCE", "1.0", "float",
         "bench-gate relative tolerance on cold-compile metrics"),
    Knob("REPRO_BENCH_COLD_ABS_S", "2.0", "float",
         "bench-gate absolute slack (seconds) on cold-compile metrics"),
    Knob("REPRO_BENCH_COLD_BUDGET_S", "13.85", "float",
         "hard ceiling on the T=512 chain's cold compile in "
         "`benchmarks/enum_ve.py`"),
]

KNOBS: Dict[str, Knob] = {k.name: k for k in _KNOB_ROWS}


def _knob(name: str) -> Knob:
    try:
        return KNOBS[name]
    except KeyError:
        raise KeyError(
            f"unknown settings knob {name!r} (see repro.settings.KNOBS)"
        ) from None


# ---------------------------------------------------------------------------
# typed getters — env wins, read at call time
# ---------------------------------------------------------------------------


def get_raw(name: str) -> Optional[str]:
    """The raw environment value, or the registered default (possibly None).
    The env var always wins; it is read on every call, never cached."""
    knob = _knob(name)
    env = os.environ.get(name)
    return env if env is not None else knob.default


def is_set(name: str) -> bool:
    """Whether the knob is explicitly set in the environment."""
    _knob(name)
    return name in os.environ


def get_str(name: str) -> str:
    value = get_raw(name)
    if value is None:
        raise ValueError(f"knob {name} has no default and is not set")
    return value


def get_bool(name: str) -> bool:
    """False iff the effective value is ``0``/``false``/``off`` (case-
    insensitive) — the truthiness convention every boolean knob shares."""
    value = get_raw(name)
    return value is not None and value.strip().lower() not in _FALSE


def get_int(name: str) -> int:
    return int(get_str(name))


def get_float(name: str) -> float:
    return float(get_str(name))


def get_optional_float(name: str) -> Optional[float]:
    value = get_raw(name)
    return None if value is None or value.strip() == "" else float(value)


# ---------------------------------------------------------------------------
# documentation surface
# ---------------------------------------------------------------------------


def describe() -> List[Dict[str, str]]:
    """Registry rows as dicts (name/default/kind/effect) in table order."""
    return [
        {"name": k.name, "default": k.default_display, "kind": k.kind,
         "effect": k.effect}
        for k in _KNOB_ROWS
    ]


def render_env_table() -> str:
    """The environment-variable reference as a markdown table — the exact
    text between the ``settings:begin``/``settings:end`` markers in
    ``docs/backends.md``. That page doctests the comparison, so the docs
    table is mechanically locked to this registry."""
    lines = [
        "| variable | default | effect |",
        "|----------|---------|--------|",
    ]
    for k in _KNOB_ROWS:
        lines.append(f"| `{k.name}` | {k.default_display} | {k.effect} |")
    return "\n".join(lines)


def documented_env_table(markdown_text: str) -> str:
    """Extract the table between the settings markers of a docs page (used
    by the drift check in docs/backends.md and tests/test_settings.py)."""
    begin, end = "<!-- settings:begin -->", "<!-- settings:end -->"
    if begin not in markdown_text or end not in markdown_text:
        raise ValueError("docs page is missing the settings table markers")
    return markdown_text.split(begin, 1)[1].split(end, 1)[0].strip()
