"""`ServableModel`: trained inference artifacts as named, servable
endpoints.

The paper's framing is that inference produces first-class objects — a
guide with fitted params, a store of posterior samples, an enumerated
decoder. This module turns each of those artifact kinds into the same
serving surface: a `CompiledServable` endpoint (pad-to-bucket batching,
compile-once per bucket, optional mesh sharding) plus a process-wide
registry so `launch/serve.py` and the micro-batcher can look endpoints up
by name.

Artifact constructors:

* `ServableModel.from_svi(name, model, guide, params)` — amortized /
  variational posterior predictive. ``params`` are the *unconstrained*
  optimizer params (``svi.optim.get_params(state.optim_state)``), the same
  tree `checkpoint.store` persists.
* `ServableModel.from_mcmc(name, model, posterior_samples)` — replays a
  posterior sample store through the model (chain-grouped samples via
  ``batch_ndims=2``).
* `ServableModel.from_discrete(name, model, data=...)` — an
  `infer_discrete`-style enumerated decoder: serves exact MAP
  (``temperature=0``) or exact joint posterior samples (``temperature=1``)
  of annotated discrete sites.
* `ServableModel.from_checkpoint(name, model, directory, guide=...)` —
  warm start: restore the latest committed step from a
  `checkpoint.store` directory (optionally resharded onto a serving mesh)
  and serve it via `from_svi`; the restored step is kept on
  ``servable.restored_step``.
* `ServableModel.from_smc(name, model_init, model_step)` — online state
  estimation: each request row is an observation *window* (row axis x time
  axis) filtered by an independent SMC sweep, returning per-step filtering
  means and the window's marginal likelihood. The servable also carries
  ``.filter_engine`` (an `SMCFilter`), which `serve/server.py` drives for
  the streaming per-session ``:filter`` route.

The serving contract for the wrapped model: it takes ONE positional
argument, the request batch pytree, whose leading dim is the batch.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

import jax

from ..core import handlers
from ..infer.predictive import Predictive
from .engine import CompiledServable


class ServableModel:
    """A named, compiled posterior-serving endpoint.

    Thin composition: `kind`/`meta` describe the artifact, `engine` is the
    bucketed compiled executor. Engine kwargs (``max_batch``, ``buckets``,
    ``mesh``, ``donate``, ``out_batch_axes``) pass through.
    """

    def __init__(self, name: str, fn: Callable, *, kind: str = "custom",
                 meta: Optional[Dict[str, Any]] = None, **engine_kwargs):
        self.name = name
        self.kind = kind
        self.meta = meta or {}
        self.restored_step: Optional[int] = None
        self.filter_engine = None  # SMCFilter for `from_smc` servables
        self.engine = CompiledServable(fn, **engine_kwargs)

    def predict(self, rng_key, batch: Any) -> Any:
        """One compiled, bucketed forward for `batch` (leading dim = rows)."""
        return self.engine(rng_key, batch)

    __call__ = predict

    def refresh(self, **updates) -> None:
        """Hot-swap artifact state in place (``params=`` for svi/checkpoint
        servables, ``samples=`` for mcmc, ``data=`` for discrete). The new
        values ride the engine's traced signature, so a same-shaped refresh
        — e.g. the next committed checkpoint step — serves immediately with
        NO recompile and the compiles == buckets contract intact."""
        if self.engine.state is None:
            raise ValueError(f"servable '{self.name}' carries no artifact state")
        for key, value in updates.items():
            if key not in self.engine.state:
                raise KeyError(
                    f"unknown state key '{key}' "
                    f"(has: {sorted(self.engine.state)})"
                )
            self.engine.state[key] = value

    @property
    def num_traces(self) -> int:
        return self.engine.num_traces

    @property
    def buckets_touched(self):
        return self.engine.buckets_touched

    def __repr__(self) -> str:
        return (
            f"ServableModel({self.name!r}, kind={self.kind!r}, "
            f"buckets={self.engine.buckets}, compiles={self.num_traces})"
        )

    # -- artifact constructors ----------------------------------------------
    @classmethod
    def from_svi(cls, name: str, model: Callable, guide: Callable, params: Dict,
                 *, num_samples: int = 1, return_sites: Optional[list] = None,
                 **engine_kwargs) -> "ServableModel":
        """Serve the (guide, params) artifact of a trained SVI run: each
        request draws `num_samples` guide samples and replays them through
        the model. ``params`` = unconstrained optimizer params."""
        pred = Predictive(
            model, guide=guide, num_samples=num_samples,
            return_sites=return_sites, jit_compile=False,  # engine owns the jit
        )
        # params ride the engine's traced signature (not baked per bucket);
        # servable.refresh(params=...) hot-swaps them with no recompile
        fn = lambda key, batch, state: pred.call_with(key, state["params"], None, batch)
        return cls(name, fn, kind="svi", state={"params": dict(params or {})},
                   meta={"num_samples": num_samples}, **engine_kwargs)

    @classmethod
    def from_mcmc(cls, name: str, model: Callable, posterior_samples: Dict,
                  *, batch_ndims: int = 1, return_sites: Optional[list] = None,
                  **engine_kwargs) -> "ServableModel":
        """Serve an MCMC sample store: every request fans the full store
        through the model (use `MCMC.get_samples(group_by_chain=True)` +
        ``batch_ndims=2`` for chain-shaped output)."""
        pred = Predictive(
            model, posterior_samples=posterior_samples, batch_ndims=batch_ndims,
            return_sites=return_sites, jit_compile=False,
        )
        # the sample store rides the engine's traced signature — one copy
        # shared by all bucket executables; refresh(samples=...) hot-swaps
        fn = lambda key, batch, state: pred.call_with(key, {}, state["samples"], batch)
        n_draws = len(jax.tree_util.tree_leaves(posterior_samples)[0])
        return cls(name, fn, kind="mcmc",
                   state={"samples": dict(posterior_samples)},
                   meta={"num_draws": n_draws}, **engine_kwargs)

    @classmethod
    def from_discrete(cls, name: str, model: Callable, *,
                      data: Optional[Dict] = None, temperature: int = 0,
                      return_sites: Optional[list] = None,
                      **engine_kwargs) -> "ServableModel":
        """Serve an enumerated decoder: exact MAP (``temperature=0``) or an
        exact joint posterior sample (``temperature=1``) of the annotated
        discrete sites, with continuous posteriors fixed via ``data``
        (e.g. SVI posterior means)."""
        from ..infer.traceenum_elbo import infer_discrete

        has_data = bool(data)

        def fn(key, batch, state):
            # conditioning values (e.g. SVI posterior means) ride the traced
            # signature; refresh(data=...) hot-swaps them
            base = (
                handlers.substitute(model, data=state["data"]) if has_data else model
            )
            key_dec, key_trace = jax.random.split(key)
            decoded = infer_discrete(base, temperature=temperature, rng_key=key_dec)
            tr = handlers.trace(handlers.seed(decoded, key_trace)).get_trace(batch)
            sites = return_sites or [
                n for n, s in tr.nodes.items()
                if s["type"] == "sample" and not s.get("is_observed")
                and (s.get("infer") or {}).get("enumerate")
            ]
            return {n: tr[n]["value"] for n in sites if n in tr.nodes}

        return cls(name, fn, kind="discrete",
                   state={"data": dict(data or {})},
                   meta={"temperature": temperature}, **engine_kwargs)

    @classmethod
    def from_smc(cls, name: str, model_init: Callable, model_step: Callable, *,
                 proposal_init: Optional[Callable] = None,
                 proposal_step: Optional[Callable] = None,
                 params: Optional[Dict] = None,
                 num_particles: int = 1000,
                 ess_threshold: float = 0.5,
                 resample_method: Optional[str] = None,
                 **engine_kwargs) -> "ServableModel":
        """Serve filtering posteriors for online state estimation.

        Batched (``:predict``) traffic: each request row is one observation
        window — leading axis rows, second axis time — and every row runs an
        independent `smc_sweep` (vmapped, so the whole batch is one compiled
        call per bucket). The response per row is ``{"means": per-step
        filtering means, "log_evidence": the window's log-marginal
        likelihood}``.

        ``params`` (e.g. `NestedVariational`-trained proposal parameters)
        ride the traced signature; ``refresh(params=...)`` hot-swaps them
        with no recompile — the same contract as `from_svi`.

        Streaming traffic: the returned servable carries ``.filter_engine``,
        an `SMCFilter` over the same programs, which `InferenceServer`'s
        per-session ``:filter`` route advances one observation at a time
        (the filter state lives server-side between requests)."""
        from ..infer.smc import (
            SMCFilter, _build_programs, _weighted_means, smc_sweep,
        )

        init_prog, step_prog = _build_programs(
            model_init, model_step, proposal_init, proposal_step,
            ess_threshold, resample_method,
        )

        def fn(key, batch, state):
            rows = jax.tree_util.tree_leaves(batch)[0].shape[0]
            keys = jax.random.split(key, rows)

            def one(k, xs):
                r = smc_sweep(
                    init_prog, step_prog, k, xs, state["params"],
                    num_particles=num_particles,
                )
                means = _weighted_means(r.history.latents, r.history.log_weights)
                return {"means": means, "log_evidence": r.log_evidence}

            return jax.vmap(one)(keys, batch)

        servable = cls(name, fn, kind="smc",
                       state={"params": dict(params or {})},
                       meta={"num_particles": num_particles},
                       **engine_kwargs)
        servable.filter_engine = SMCFilter(
            model_init, model_step,
            proposal_init=proposal_init, proposal_step=proposal_step,
            num_particles=num_particles, ess_threshold=ess_threshold,
            resample_method=resample_method,
        )
        return servable

    @classmethod
    def from_checkpoint(cls, name: str, model: Callable, directory: str, *,
                        guide: Callable, step: Optional[int] = None,
                        template: Any = None, shardings: Any = None,
                        num_samples: int = 1,
                        return_sites: Optional[list] = None,
                        guide_args: tuple = (),
                        guide_kwargs: Optional[Dict[str, Any]] = None,
                        **engine_kwargs) -> "ServableModel":
        """Warm start from a `checkpoint.store` directory: restore the
        latest committed step (or ``step``), treat the tree as the
        unconstrained SVI params (a ``"params"`` sub-tree is used when
        present, so full-state checkpoints work too), and serve it.

        A freshly constructed autoguide must see the model in *training*
        configuration once, or it will treat serving-time-unobserved sites
        (``obs=None``) as latents the checkpoint has no params for. Pass
        ``guide_args``/``guide_kwargs`` shaped like the training call
        (dummy values are fine — only observedness and event shapes
        matter) and the guide's prototype is set up here before serving."""
        from ..checkpoint.store import restore, restore_latest

        if step is None:
            restored_step, tree = restore_latest(
                directory, template=template, shardings=shardings
            )
        else:
            restored_step, tree = restore(
                directory, step, template=template, shardings=shardings
            )
        params = tree["params"] if isinstance(tree, dict) and "params" in tree else tree
        if guide_args or guide_kwargs:
            # one seeded eager call sets up the guide prototype in training
            # configuration (lazy autoguides trace the model here)
            handlers.trace(handlers.seed(guide, jax.random.PRNGKey(0))).get_trace(
                *guide_args, **(guide_kwargs or {})
            )
        servable = cls.from_svi(
            name, model, guide, params, num_samples=num_samples,
            return_sites=return_sites, **engine_kwargs
        )
        servable.kind = "checkpoint"
        servable.restored_step = restored_step
        servable.meta["directory"] = directory
        return servable


# ---------------------------------------------------------------------------
# process-wide registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ServableModel] = {}
_LOCK = threading.Lock()


def register(servable: ServableModel, *, replace: bool = False) -> ServableModel:
    """Register under ``servable.name``; re-registering an existing name
    requires ``replace=True`` (hot swap after a checkpoint refresh)."""
    with _LOCK:
        if servable.name in _REGISTRY and not replace:
            raise ValueError(
                f"servable '{servable.name}' already registered "
                f"(pass replace=True to hot-swap)"
            )
        _REGISTRY[servable.name] = servable
    return servable


def get_servable(name: str) -> ServableModel:
    with _LOCK:
        if name not in _REGISTRY:
            raise KeyError(
                f"no servable '{name}' (registered: {sorted(_REGISTRY) or 'none'})"
            )
        return _REGISTRY[name]


def unregister(name: str) -> None:
    with _LOCK:
        _REGISTRY.pop(name, None)


def list_servables() -> List[str]:
    with _LOCK:
        return sorted(_REGISTRY)


def clear_registry() -> None:
    with _LOCK:
        _REGISTRY.clear()
