"""HTTP front end for the posterior-serving stack (stdlib only).

`InferenceServer` puts a `ThreadingHTTPServer` in front of the process
servable registry: every registered `ServableModel` gets its own
`MicroBatcher`, so uncoordinated HTTP requests coalesce into few large
compiled forwards, with deadline-aware admission control (HTTP 429 +
``Retry-After`` when the projected queue wait exceeds the request
deadline) and hot-swap endpoints for the streaming trainer.

Routes (all JSON):

    GET  /healthz                          liveness + model count
    GET  /v1/models                        multi-model registry listing
    GET  /v1/models/<name>                 one model's metadata
    GET  /v1/models/<name>/stats           ServeStats summary + num_traces
    POST /v1/models/<name>:predict         {"inputs": ..., "deadline_ms": ...}
    POST /v1/models/<name>:filter          {"session": ..., "observation": ...}
    POST /admin/models/<name>/refresh      hot-swap from a checkpoint dir
    POST /admin/device-loss                plan_remesh for surviving hosts

The ``:filter`` route is the streaming traffic pattern for `from_smc`
servables: each ``session`` holds a device-resident `SMCFilter` state
server-side, advanced one observation per request (first request — or
``"reset": true`` — initializes it). Responses carry the session's step
count, per-site filtering means, ESS, and running log-evidence. Unlike
``:predict``, filter requests are ordered per session, so they bypass the
micro-batcher; the compiled `SMCFilter.update` is the whole cost.

Request deadline precedence: per-request ``deadline_ms`` in the body >
the ``REPRO_SERVE_DEADLINE_MS`` knob > no deadline (requests always
queue). Prediction inputs: a nested list becomes one array request batch;
a dict of nested lists becomes a dict-of-arrays pytree. The leading axis
is always the request's row count.

The server binds 127.0.0.1 and an OS-assigned free port by default —
`launch/stream.py` prints the resolved address; tests drive a live
server through real sockets.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional

import jax
import numpy as np

from .. import settings
from ..distributed.fault_tolerance import plan_remesh
from .batcher import LoadShedError, MicroBatcher
from .registry import ServableModel


def _to_batch(inputs: Any) -> Any:
    """JSON inputs -> request pytree (leading dim = rows)."""
    if isinstance(inputs, dict):
        return {k: jax.numpy.asarray(np.asarray(v)) for k, v in inputs.items()}
    return jax.numpy.asarray(np.asarray(inputs))


def _to_json(tree: Any) -> Any:
    """Output pytree -> JSON-serializable nested lists."""
    return jax.tree.map(lambda x: np.asarray(x).tolist(), tree)


class InferenceServer:
    """N servables, one mesh, one HTTP port.

    ``models`` maps name -> `ServableModel`; each gets a `MicroBatcher`
    (per-model ``max_wait_ms`` via ``batcher_kwargs``). `default_deadline_ms`
    (fallback: the ``REPRO_SERVE_DEADLINE_MS`` knob) applies to requests
    that don't carry their own deadline."""

    def __init__(
        self,
        models: Dict[str, ServableModel],
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        default_deadline_ms: Optional[float] = None,
        chips_per_host: int = 4,
        model_parallelism: int = 1,
        **batcher_kwargs,
    ):
        self.models = dict(models)
        self.batchers: Dict[str, MicroBatcher] = {
            name: MicroBatcher(servable, **batcher_kwargs)
            for name, servable in self.models.items()
        }
        if default_deadline_ms is None:
            default_deadline_ms = settings.get_optional_float("REPRO_SERVE_DEADLINE_MS")
        self.default_deadline_ms = default_deadline_ms
        self.chips_per_host = chips_per_host
        self.model_parallelism = model_parallelism
        # streaming filter sessions: (model, session id) -> FilterState
        self._filter_states: Dict[tuple, Any] = {}
        self._filter_lock = threading.Lock()
        self._httpd = ThreadingHTTPServer((host, port), _make_handler(self))
        self._httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    @property
    def address(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "InferenceServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.05},
            daemon=True,
        )
        self._thread.start()
        return self

    def close(self) -> None:
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=10.0)
            self._thread = None
        self._httpd.server_close()
        for batcher in self.batchers.values():
            batcher.close()

    def __enter__(self) -> "InferenceServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- route logic (transport-independent; the handler is a thin shim) -----
    def model_info(self, name: str) -> Dict[str, Any]:
        servable = self.models[name]
        return {
            "name": name,
            "kind": servable.kind,
            "buckets": list(servable.engine.buckets),
            "num_traces": servable.num_traces,
            "restored_step": servable.restored_step,
            "meta": {k: v for k, v in servable.meta.items()
                     if isinstance(v, (str, int, float, bool, type(None)))},
        }

    def handle_get(self, path: str) -> tuple:
        if path == "/healthz":
            return 200, {"ok": True, "models": sorted(self.models)}
        if path == "/v1/models":
            return 200, {"models": [self.model_info(n) for n in sorted(self.models)]}
        if path.startswith("/v1/models/"):
            rest = path[len("/v1/models/"):]
            name, _, tail = rest.partition("/")
            if name not in self.models:
                return 404, {"error": f"no model '{name}'"}
            if tail == "stats":
                stats = dict(self.batchers[name].stats.summary())
                stats["num_traces"] = self.models[name].num_traces
                stats["projected_wait_ms"] = round(
                    self.batchers[name].projected_wait_ms(), 3
                )
                return 200, stats
            if tail == "":
                return 200, self.model_info(name)
        return 404, {"error": f"no route {path}"}

    def handle_post(self, path: str, body: Dict[str, Any]) -> tuple:
        if path.startswith("/v1/models/") and path.endswith(":predict"):
            name = path[len("/v1/models/"):-len(":predict")]
            if name not in self.models:
                return 404, {"error": f"no model '{name}'"}
            return self._predict(name, body)
        if path.startswith("/v1/models/") and path.endswith(":filter"):
            name = path[len("/v1/models/"):-len(":filter")]
            if name not in self.models:
                return 404, {"error": f"no model '{name}'"}
            return self._filter(name, body)
        if path.startswith("/admin/models/") and path.endswith("/refresh"):
            name = path[len("/admin/models/"):-len("/refresh")]
            if name not in self.models:
                return 404, {"error": f"no model '{name}'"}
            return self._refresh(name, body)
        if path == "/admin/device-loss":
            return self._device_loss(body)
        return 404, {"error": f"no route {path}"}

    def _predict(self, name: str, body: Dict[str, Any]) -> tuple:
        if "inputs" not in body:
            return 400, {"error": "missing 'inputs'"}
        try:
            batch = _to_batch(body["inputs"])
        except Exception as e:  # noqa: BLE001 — malformed client payload
            return 400, {"error": f"bad inputs: {e}"}
        deadline_ms = body.get("deadline_ms", self.default_deadline_ms)
        try:
            out = self.batchers[name].predict(batch, deadline_ms=deadline_ms)
        except LoadShedError as e:
            return 429, {
                "error": "shed",
                "projected_wait_ms": round(e.projected_wait_ms, 3),
                "deadline_ms": e.deadline_ms,
                "retry_after_ms": round(e.retry_after_ms, 3),
            }
        except ValueError as e:
            return 400, {"error": str(e)}
        return 200, {"outputs": _to_json(out)}

    def _filter(self, name: str, body: Dict[str, Any]) -> tuple:
        """Streaming SMC: advance one observation through the session's
        server-side filter state. The first request for a session (or
        ``"reset": true``) initializes the filter from the observation; the
        session key is derived deterministically from the session id, so a
        replayed stream reproduces bit-for-bit."""
        import zlib

        servable = self.models[name]
        if servable.filter_engine is None:
            return 400, {
                "error": f"model '{name}' is not an SMC servable "
                         f"(kind={servable.kind!r}; build it with "
                         "ServableModel.from_smc for streaming filtering)"
            }
        if "observation" not in body:
            return 400, {"error": "missing 'observation'"}
        session = str(body.get("session", "default"))
        try:
            y = _to_batch(body["observation"])
        except Exception as e:  # noqa: BLE001 — malformed client payload
            return 400, {"error": f"bad observation: {e}"}
        eng = servable.filter_engine
        params = (servable.engine.state or {}).get("params", {})
        skey = (name, session)
        with self._filter_lock:
            state = None if body.get("reset") else self._filter_states.get(skey)
            if state is None:
                rng = jax.random.PRNGKey(zlib.crc32(session.encode()) & 0x7FFFFFFF)
                state, info = eng.init_state(rng, y, params=params)
            else:
                state, info = eng.update(state, y, params=params)
            self._filter_states[skey] = state
        return 200, {
            "session": session,
            "t": int(state.t),
            "means": _to_json(info["means"]),
            "ess": float(info["ess"]),
            "resampled": bool(info["resampled"]),
            "log_evidence": float(info["log_evidence"]),
        }

    def _refresh(self, name: str, body: Dict[str, Any]) -> tuple:
        """Hot-swap `name` from a committed checkpoint directory. The swap is
        a state mutation on the live engine — in-flight requests finish on
        the old params, new requests see the new ones, nothing recompiles."""
        from ..checkpoint.store import restore_latest

        servable = self.models[name]
        directory = body.get("directory") or servable.meta.get("directory")
        if not directory:
            return 400, {"error": "no checkpoint directory (pass 'directory')"}
        traces_before = servable.num_traces
        try:
            step, tree = restore_latest(directory)
        except FileNotFoundError as e:
            return 409, {"error": str(e)}
        params = tree["params"] if isinstance(tree, dict) and "params" in tree else tree
        servable.refresh(params=params)
        servable.restored_step = step
        return 200, {
            "name": name,
            "restored_step": step,
            "num_traces": servable.num_traces,
            "recompiled": servable.num_traces != traces_before,
        }

    def _device_loss(self, body: Dict[str, Any]) -> tuple:
        """Simulated device loss: report the largest viable mesh for the
        survivors (the elastic re-mesh `restore(..., shardings=...)` path)."""
        try:
            n_hosts_alive = int(body["n_hosts_alive"])
        except (KeyError, TypeError, ValueError):
            return 400, {"error": "missing/invalid 'n_hosts_alive'"}
        plan = plan_remesh(
            n_hosts_alive,
            chips_per_host=int(body.get("chips_per_host", self.chips_per_host)),
            model_parallelism=int(
                body.get("model_parallelism", self.model_parallelism)
            ),
        )
        if plan is None:
            return 507, {
                "error": "no viable mesh: survivors cannot fit one model replica",
                "n_hosts_alive": n_hosts_alive,
            }
        plan = dict(plan)
        plan["mesh_shape"] = list(plan["mesh_shape"])
        plan["axes"] = list(plan["axes"])
        return 200, {"plan": plan, "models": sorted(self.models)}


def _make_handler(server: InferenceServer):
    class Handler(BaseHTTPRequestHandler):
        # one InferenceServer per handler class — closure, not global state
        def _send(self, status: int, payload: Dict[str, Any]) -> None:
            data = json.dumps(payload).encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(data)))
            if status == 429 and "retry_after_ms" in payload:
                # Retry-After is whole seconds; round up so clients never
                # retry into the same overloaded window
                self.send_header(
                    "Retry-After",
                    str(max(1, int(-(-payload["retry_after_ms"] // 1000)))),
                )
            self.end_headers()
            self.wfile.write(data)

        def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
            try:
                status, payload = server.handle_get(self.path)
            except Exception as e:  # noqa: BLE001 — fail the request, not the server
                status, payload = 500, {"error": str(e)}
            self._send(status, payload)

        def do_POST(self):  # noqa: N802 — BaseHTTPRequestHandler API
            try:
                length = int(self.headers.get("Content-Length", 0))
                raw = self.rfile.read(length) if length else b"{}"
                body = json.loads(raw or b"{}")
                if not isinstance(body, dict):
                    raise ValueError("body must be a JSON object")
            except Exception as e:  # noqa: BLE001
                self._send(400, {"error": f"bad request body: {e}"})
                return
            try:
                status, payload = server.handle_post(self.path, body)
            except Exception as e:  # noqa: BLE001
                status, payload = 500, {"error": str(e)}
            self._send(status, payload)

        def log_message(self, fmt, *args):  # silence per-request stderr spam
            pass

    return Handler
