"""StreamingTrainer: the background half of the streaming inference
service — posteriors that never go stale.

The serve engine ships a frozen artifact; this closes the production loop
around it:

    data stream --> Prefetcher --> incremental SVI steps   (trainer thread)
                                       | every ckpt_every steps
                                       v
                              AsyncCheckpointer.save_async
                                       | on_commit(step)   (writer thread)
                                       v
                     restore_latest -> servable.refresh(params=...)
                                       |
                                       v
                        live traffic sees the new posterior

Hot-swap contract: the servable's params ride the engine's *traced* jit
signature, so `refresh()` with a same-shaped tree swaps what every compiled
bucket executable computes with — zero recompiles (``num_traces`` is
unchanged) and zero dropped requests (in-flight batches finish on whichever
params they were submitted against; there is no tear-down). The
refresh-under-traffic test and `benchmarks/serve_bench.py` assert both.

The trainer holds the SVI compile-once contract too: every step goes
through `svi.update_jit` with same-shaped batches, so `svi.num_traces`
stays 1 for the life of the stream.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Iterable, Optional

import jax

from ..checkpoint.store import AsyncCheckpointer, restore_latest
from ..infer.svi import SVI, SVIState


def hot_swap_on_commit(servable, directory: str,
                       log: Optional[Callable[[str], None]] = None):
    """The standard commit callback: restore the just-committed checkpoint
    and hot-swap it into `servable` (an svi/checkpoint `ServableModel`).
    Runs on the checkpoint writer thread, strictly after the manifest
    rename, so the server can never observe a torn checkpoint."""

    def on_commit(step: int) -> None:
        _, tree = restore_latest(directory)
        params = tree["params"] if isinstance(tree, dict) and "params" in tree else tree
        servable.refresh(params=params)
        servable.restored_step = step
        if log is not None:
            log(f"hot-swapped '{servable.name}' to checkpoint step {step}")

    return on_commit


class StreamingTrainer:
    """Run incremental SVI steps over a batch stream on a background
    thread, checkpointing asynchronously and firing ``on_commit`` after
    each committed step (see `hot_swap_on_commit`).

    Parameters
    ----------
    svi: the `SVI` engine (its `update_jit` is the hot loop).
    stream: iterable of batch pytrees; each yields the positional argument
        of one ``svi.update_jit(state, batch)`` call (wrap it in
        `data.pipeline.Prefetcher` to overlap generation with the step).
        A finite stream ends the trainer cleanly.
    state: initial `SVIState` (from ``svi.init``); required.
    directory: checkpoint directory (`checkpoint.store` layout).
    ckpt_every: checkpoint cadence in steps; the final step always
        checkpoints so a finite stream's last posterior is never lost.
    on_commit: ``f(step)`` run on the writer thread after each commit.
    max_steps: stop after this many steps even on an infinite stream.
    """

    def __init__(
        self,
        svi: SVI,
        stream: Iterable[Any],
        *,
        state: SVIState,
        directory: str,
        ckpt_every: int = 50,
        max_keep: int = 3,
        on_commit: Optional[Callable[[int], None]] = None,
        max_steps: Optional[int] = None,
    ):
        if ckpt_every < 1:
            raise ValueError("ckpt_every must be >= 1")
        self.svi = svi
        self.stream = stream
        self.state = state
        self.directory = directory
        self.ckpt_every = ckpt_every
        self.on_commit = on_commit
        self.max_steps = max_steps
        self.checkpointer = AsyncCheckpointer(directory, max_keep=max_keep)
        self.steps_done = 0
        self.last_loss: Optional[float] = None
        self.last_committed_step: Optional[int] = None
        self.error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "StreamingTrainer":
        if self._thread is not None:
            raise RuntimeError("trainer already started")
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = 30.0) -> None:
        """Signal the loop to stop after the current step, then wait for the
        final checkpoint to commit (idempotent)."""
        self._stop.set()
        self.join(timeout)

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)
        if self.error is not None:
            raise self.error

    def wait_for_commit(self, step: Optional[int] = None,
                        timeout: float = 30.0) -> int:
        """Block until a checkpoint at >= `step` (default: any) has
        committed; returns the committed step. Test/benchmark helper."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            done = self.last_committed_step
            if done is not None and (step is None or done >= step):
                return done
            if self.error is not None:
                raise self.error
            time.sleep(0.005)
        raise TimeoutError(
            f"no checkpoint commit at step >= {step} within {timeout}s"
        )

    def __enter__(self) -> "StreamingTrainer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    # -- the loop ------------------------------------------------------------
    def _checkpoint(self, step: int) -> None:
        # the servable consumes *unconstrained* optimizer params (what
        # `ServableModel.from_svi` / `from_checkpoint` expect), nested under
        # "params" so full-state checkpoints stay distinguishable
        params = self.svi.optim.get_params(self.state.optim_state)

        def commit(committed_step: int) -> None:
            if self.on_commit is not None:
                self.on_commit(committed_step)
            self.last_committed_step = committed_step

        self.checkpointer.save_async(step, {"params": params}, on_commit=commit)

    def _run(self) -> None:
        try:
            stepped_since_ckpt = False
            for batch in self.stream:
                if self._stop.is_set():
                    break
                if self.max_steps is not None and self.steps_done >= self.max_steps:
                    break
                self.state, loss = self.svi.update_jit(self.state, batch)
                self.steps_done += 1
                stepped_since_ckpt = True
                if self.steps_done % self.ckpt_every == 0:
                    # block on the loss first: update_jit is async-dispatched,
                    # and snapshotting params mid-donation would be a race
                    self.last_loss = float(jax.block_until_ready(loss))
                    self._checkpoint(self.steps_done)
                    stepped_since_ckpt = False
                else:
                    self.last_loss = float(loss)
            if stepped_since_ckpt:
                self._checkpoint(self.steps_done)
            self.checkpointer.wait()
        except BaseException as e:  # noqa: BLE001 — surfaced via join()
            self.error = e
