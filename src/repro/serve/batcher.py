"""Dynamic micro-batching for the posterior-serving engine.

Production predictive traffic arrives as many small, uncoordinated
requests; the accelerator wants few large batches. `MicroBatcher` bridges
the two: requests enter an async queue, a worker coalesces them — up to
``max_batch`` rows or ``max_wait_ms`` after the first request of a batch,
whichever comes first — runs ONE forward through a `CompiledServable`
(pad-to-bucket, so the coalesced size still maps onto a compiled bucket),
and scatters the per-request slices back to each caller's future. Global
(non-batch) output leaves — e.g. posterior draws of shared latents — are
handed to every request in the batch whole.

Randomness contract: each *coalesced batch* consumes one fold of the
batcher's base key, so results are deterministic given the arrival
grouping; requests coalesced together share the same posterior draws
(that is what one sharded forward means).

Overload behavior is *admission control*, not queue growth: a request
submitted with ``deadline_ms=`` is rejected with `LoadShedError` (HTTP 429
at the front end) when the projected queue wait — admitted-but-incomplete
rows over max_batch forwards at the EWMA batch service time — exceeds its
deadline. Requests without a deadline always queue.

`ServeStats` is the observability surface: per-request latency quantiles
(p50/p99), lifetime throughput, queue depth at batch formation, padding
waste, and the engine's retrace counter — `launch/serve.py` prints it and
`benchmarks/serve_bench.py` persists it to BENCH_serve.json.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax

from .engine import CompiledServable, batch_count


def _percentile(sorted_vals: List[float], p: float) -> float:
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1, int(round(p / 100.0 * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


class LoadShedError(RuntimeError):
    """Raised by `MicroBatcher.submit` when the projected queue wait exceeds
    the request's deadline — the request is rejected *before* queueing so the
    client can retry elsewhere instead of timing out in line. HTTP front
    ends map this to 429 with ``retry_after_ms`` as the Retry-After hint."""

    def __init__(self, projected_wait_ms: float, deadline_ms: float):
        self.projected_wait_ms = projected_wait_ms
        self.deadline_ms = deadline_ms
        self.retry_after_ms = max(projected_wait_ms - deadline_ms, 1.0)
        super().__init__(
            f"shed: projected queue wait {projected_wait_ms:.1f}ms exceeds "
            f"deadline {deadline_ms:.1f}ms"
        )


@dataclass
class ServeStats:
    """Rolling serving metrics (thread-safe via the batcher's worker being
    the only writer; readers snapshot)."""

    window: int = 4096
    requests: int = 0
    batches: int = 0
    rows: int = 0
    padded_rows: int = 0
    shed: int = 0
    max_queue_depth: int = 0
    started_at: float = field(default_factory=time.perf_counter)
    latencies_ms: List[float] = field(default_factory=list)
    batch_sizes: List[int] = field(default_factory=list)

    def record_batch(
        self,
        n_requests: int,
        n_rows: int,
        bucket: int,
        queue_depth: int,
        latencies_ms: List[float],
    ) -> None:
        self.requests += n_requests
        self.batches += 1
        self.rows += n_rows
        self.padded_rows += bucket - n_rows
        self.max_queue_depth = max(self.max_queue_depth, queue_depth)
        self.batch_sizes.append(n_rows)
        self.latencies_ms.extend(latencies_ms)
        if len(self.latencies_ms) > self.window:
            self.latencies_ms = self.latencies_ms[-self.window :]
        if len(self.batch_sizes) > self.window:
            self.batch_sizes = self.batch_sizes[-self.window :]

    def summary(self) -> Dict[str, Any]:
        lat = sorted(self.latencies_ms)
        elapsed = max(time.perf_counter() - self.started_at, 1e-9)
        total = max(self.rows + self.padded_rows, 1)
        return {
            "requests": self.requests,
            "batches": self.batches,
            "rows": self.rows,
            "requests_per_sec": round(self.requests / elapsed, 2),
            "rows_per_sec": round(self.rows / elapsed, 2),
            "p50_ms": round(_percentile(lat, 50), 3),
            "p99_ms": round(_percentile(lat, 99), 3),
            "mean_batch_rows": round(sum(self.batch_sizes) / max(len(self.batch_sizes), 1), 2),
            "max_queue_depth": self.max_queue_depth,
            "pad_waste": round(self.padded_rows / total, 4),
            "shed": self.shed,
            "shed_rate": round(self.shed / max(self.requests + self.shed, 1), 4),
        }


@dataclass
class _Request:
    batch: Any
    n: int
    future: Future
    t_submit: float


_STOP = object()


class MicroBatcher:
    """Async request queue -> coalesce -> one sharded forward -> scatter.

    Parameters
    ----------
    servable: a `CompiledServable` or a `ServableModel` (its engine is used).
    max_batch: coalesce at most this many rows per forward (defaults to the
        engine's largest bucket).
    max_wait_ms: after the first request of a batch arrives, wait at most
        this long for more before running (latency/throughput knob).
    rng_key: base PRNG key; batch ``i`` uses ``fold_in(rng_key, i)``.
    """

    def __init__(
        self,
        servable: CompiledServable,
        *,
        max_batch: Optional[int] = None,
        max_wait_ms: float = 2.0,
        rng_key=None,
        stats_window: int = 4096,
    ):
        # accept a ServableModel directly (its engine carries the jit cache)
        servable = getattr(servable, "engine", servable)
        self.servable = servable
        self.max_batch = int(max_batch or servable.max_batch)
        if self.max_batch > servable.max_batch:
            raise ValueError(
                f"max_batch {self.max_batch} exceeds the engine's largest "
                f"bucket {servable.max_batch}"
            )
        self.max_wait_s = max_wait_ms / 1e3
        self._base_key = rng_key if rng_key is not None else jax.random.PRNGKey(0)
        self._batch_counter = 0
        self.stats = ServeStats(window=stats_window)
        self._q: queue.Queue = queue.Queue()
        self._carry: Optional[_Request] = None
        self._closed = False
        # load-shed bookkeeping: rows admitted but not yet completed, and an
        # EWMA of per-batch service time — together they give submit() a
        # projected queue wait without touching the worker thread
        self._pending_rows = 0
        self._ewma_batch_s: Optional[float] = None
        self._ewma_alpha = 0.2
        # guards the closed-check + enqueue pair: without it, a submit that
        # passes the check while close() runs could land its request after
        # the shutdown drain, leaving the future forever unresolved
        self._submit_lock = threading.Lock()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    # -- client API ----------------------------------------------------------
    def projected_wait_ms(self, n_rows: int = 1) -> float:
        """Estimated queue wait for a new `n_rows` request: admitted-but-
        incomplete rows ahead of it, divided into max_batch forwards, each
        costing the EWMA batch service time (plus one coalesce window).
        0.0 until the first batch has been measured — the batcher never
        sheds on a cold queue."""
        with self._submit_lock:
            ewma, pending = self._ewma_batch_s, self._pending_rows
        if ewma is None:
            return 0.0
        batches_ahead = (pending + n_rows) / self.max_batch
        return (batches_ahead * ewma + self.max_wait_s) * 1e3

    def submit(self, batch: Any, *, deadline_ms: Optional[float] = None) -> Future:
        """Enqueue a request pytree (leading dim = rows); returns a Future
        resolving to the per-request output slice.

        With ``deadline_ms``, the request is admitted only if the projected
        queue wait fits the deadline; otherwise it is rejected immediately
        with `LoadShedError` (HTTP 429 at the front end) instead of joining
        a queue it cannot clear in time."""
        n = batch_count(batch)
        if n > self.max_batch:
            raise ValueError(
                f"request of {n} rows exceeds max_batch={self.max_batch}; "
                f"split it client-side"
            )
        req = _Request(batch, n, Future(), time.perf_counter())
        with self._submit_lock:
            if self._closed:
                raise RuntimeError("MicroBatcher is closed")
            if deadline_ms is not None and self._ewma_batch_s is not None:
                batches_ahead = (self._pending_rows + n) / self.max_batch
                projected = (batches_ahead * self._ewma_batch_s + self.max_wait_s) * 1e3
                if projected > deadline_ms:
                    self.stats.shed += 1
                    raise LoadShedError(projected, deadline_ms)
            self._pending_rows += n
            self._q.put(req)
        return req.future

    def predict(self, batch: Any, timeout: Optional[float] = None,
                deadline_ms: Optional[float] = None) -> Any:
        """Blocking convenience: submit + wait."""
        return self.submit(batch, deadline_ms=deadline_ms).result(timeout)

    def close(self, timeout: Optional[float] = 10.0) -> None:
        """Drain the queue and stop the worker (idempotent)."""
        with self._submit_lock:
            if not self._closed:
                self._closed = True
                self._q.put(_STOP)
        self._worker.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- worker --------------------------------------------------------------
    def _next_group(self):
        """Block for the first request, then coalesce until max_batch rows
        or the deadline. Returns (group, stopping)."""
        first = self._carry
        self._carry = None
        if first is None:
            first = self._q.get()
            if first is _STOP:
                return [], True
        group, total = [first], first.n
        deadline = time.perf_counter() + self.max_wait_s
        stopping = False
        while total < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                nxt = self._q.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is _STOP:
                stopping = True
                break
            if total + nxt.n > self.max_batch:
                self._carry = nxt  # head-of-line for the next batch
                break
            group.append(nxt)
            total += nxt.n
        return group, stopping

    def _run_group(self, group: List[_Request]) -> None:
        depth = self._q.qsize() + (1 if self._carry is not None else 0)
        key = jax.random.fold_in(self._base_key, self._batch_counter)
        self._batch_counter += 1
        total = sum(r.n for r in group)
        t_start = time.perf_counter()
        try:
            coalesced = jax.tree.map(
                lambda *xs: jax.numpy.concatenate(xs, axis=0), *[r.batch for r in group]
            )
            out = self.servable(key, coalesced)
            out = jax.block_until_ready(out)
            t_done = time.perf_counter()
            offset = 0
            latencies = []
            for r in group:
                r.future.set_result(
                    self.servable.slice_output(out, offset, offset + r.n)
                )
                offset += r.n
                latencies.append((t_done - r.t_submit) * 1e3)
        except Exception as e:  # noqa: BLE001 — scattered to callers
            for r in group:
                if not r.future.done():
                    r.future.set_exception(e)
            with self._submit_lock:
                self._pending_rows = max(self._pending_rows - total, 0)
            return
        service_s = t_done - t_start
        with self._submit_lock:
            self._pending_rows = max(self._pending_rows - total, 0)
            if self._ewma_batch_s is None:
                self._ewma_batch_s = service_s
            else:
                a = self._ewma_alpha
                self._ewma_batch_s = a * service_s + (1 - a) * self._ewma_batch_s
        from .engine import bucket_for

        self.stats.record_batch(
            n_requests=len(group),
            n_rows=total,
            bucket=bucket_for(total, self.servable.buckets),
            queue_depth=depth,
            latencies_ms=latencies,
        )

    def _loop(self) -> None:
        while True:
            group, stopping = self._next_group()
            if group:
                self._run_group(group)
            if stopping:
                # drain anything still queued so no future is left dangling
                while True:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is not _STOP:
                        self._run_group([nxt])
                if self._carry is not None:
                    self._run_group([self._carry])
                    self._carry = None
                return
