"""Compile-once, shape-bucketed execution for posterior serving.

The training side of the repo already guarantees "one executable, every
step" (`SVI.update_jit`, the MCMC single-call engine). This module gives
the *read* path the same contract under production traffic, where request
batch sizes vary per call: incoming batches are padded up to a small set
of **shape buckets** (powers of two by default), so the number of XLA
compiles is bounded by the number of buckets — never by the number of
distinct request sizes. `num_traces` reports exactly how many executables
exist; a steady-state server must satisfy ``num_traces ==
len(buckets_touched)``, and `benchmarks/serve_bench.py` gates on it.

Key properties:

* **pad-to-bucket batching** — leading (batch) dims are edge-padded to the
  bucket size inside the engine; outputs are sliced back, so callers never
  see padding. Edge padding (repeat the last row) keeps padded rows inside
  the model's support (zeros may not be, e.g. for simplex-valued inputs).
* **batch-axis discovery** — which output axes carry the request batch is
  discovered structurally with two `jax.eval_shape` probes (no compile, no
  FLOPs): an axis that grows with the probe batch size is a batch axis.
  Global leaves (posterior draws of latents shared across the batch) are
  returned whole.
* **mesh sharding** — with ``mesh=``, the batch is constrained onto the
  mesh's data axes via the same `distributed.sharding` policy SVI and MCMC
  use; a 1-device mesh is bit-identical to no mesh.
* **donation** — the padded input buffer is engine-owned (callers keep
  their arrays), so it is donated to XLA on backends that support buffer
  donation (auto-disabled on CPU, where XLA ignores donation).

Example::

    >>> import jax, jax.numpy as jnp
    >>> from repro.serve.engine import CompiledServable
    >>> def double(key, batch):
    ...     return {"y": 2.0 * batch["x"], "global": jnp.zeros(3)}
    >>> eng = CompiledServable(double, max_batch=8)
    >>> out = eng(jax.random.PRNGKey(0), {"x": jnp.arange(3.0)})
    >>> out["y"].shape, out["global"].shape
    ((3,), (3,))
    >>> _ = eng(jax.random.PRNGKey(0), {"x": jnp.arange(4.0)})  # same bucket
    >>> eng.num_traces, sorted(eng.buckets_touched)
    (1, [4])
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def default_buckets(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to ``max_batch`` (plus ``max_batch`` itself when it
    is not a power of two): 64 -> (1, 2, 4, 8, 16, 32, 64)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (buckets must be sorted ascending)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"batch of {n} exceeds the largest bucket {buckets[-1]}; raise "
        f"max_batch or split the request client-side"
    )


def batch_count(batch: Any) -> int:
    """Leading-dim size of a request pytree; every leaf must agree."""
    leaves = jax.tree_util.tree_leaves(batch)
    if not leaves:
        raise ValueError("empty request batch")
    sizes = {leaf.shape[0] if getattr(leaf, "ndim", 0) else None for leaf in leaves}
    if None in sizes or len(sizes) != 1:
        raise ValueError(
            f"request leaves disagree on the leading batch dim: {sizes}"
        )
    n = sizes.pop()
    if n < 1:
        raise ValueError("request batch has 0 rows")
    return n


def pad_leading(batch: Any, total: int, *, force_copy: bool = False) -> Any:
    """Edge-pad every leaf's leading dim to ``total`` rows. With
    ``force_copy`` the result never aliases the input (so the engine can
    donate it even when no padding was needed)."""

    def leaf(x):
        if getattr(x, "weak_type", False):
            # canonicalize to a strong dtype: jnp.pad drops weak_type, so a
            # bucket-sized (pad == 0) weak-typed batch would otherwise carry
            # a different aval than a padded one and retrace the same bucket
            x = x.astype(x.dtype)
        pad = total - x.shape[0]
        if pad < 0:
            raise ValueError(f"batch of {x.shape[0]} larger than bucket {total}")
        if pad == 0:
            return jnp.array(x, copy=True) if force_copy else x
        return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1), mode="edge")

    return jax.tree.map(leaf, batch)


class CompiledServable:
    """Wrap ``fn(rng_key, batch) -> pytree`` with pad-to-bucket batching and
    a single shared jit cache (compiles == buckets touched).

    fn must be jit-traceable and treat ``batch``'s leading dim as the
    request batch. Outputs may mix batch-axis leaves (per-request rows) and
    global leaves (shared across the batch) — the split is discovered
    automatically, or passed explicitly via ``out_batch_axes`` (a dict
    keyed like a flat dict output, values int axis or None).
    """

    def __init__(
        self,
        fn: Callable,
        *,
        max_batch: int = 64,
        buckets: Optional[Sequence[int]] = None,
        mesh=None,
        donate: Optional[bool] = None,
        out_batch_axes: Optional[Dict[str, Optional[int]]] = None,
        state: Any = None,
    ):
        self.fn = fn
        # Artifact state (params / posterior samples / conditioning data)
        # threaded through the jit signature as a TRACED pytree: with N
        # buckets the state is passed at call time instead of being baked
        # into N executables as XLA constants, and a same-shaped update
        # (checkpoint refresh) serves immediately with no recompile. When
        # given, fn is called as fn(key, batch, state); decide at
        # construction — flipping later would change the traced signature.
        self.state = state
        self._has_state = state is not None
        self.buckets = tuple(sorted(set(buckets))) if buckets else default_buckets(max_batch)
        self.max_batch = self.buckets[-1]
        self.mesh = mesh
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        self._explicit_axes = out_batch_axes
        self._axes: Optional[list] = None  # flattened Optional[int] per out leaf
        self.buckets_touched: set = set()
        self._jit = jax.jit(
            self._forward, donate_argnums=(1,) if self.donate else ()
        )

    # -- compiled forward ---------------------------------------------------
    def _call_fn(self, rng_key, batch, state):
        if self._has_state:
            return self.fn(rng_key, batch, state)
        return self.fn(rng_key, batch)

    def _forward(self, rng_key, batch, state):
        if self.mesh is not None:
            from ..distributed.sharding import shard_batch

            batch = shard_batch(batch, self.mesh)
        return self._call_fn(rng_key, batch, state)

    @property
    def num_traces(self) -> int:
        """Compiled executables in the shared jit cache. The serving
        contract: equal to ``len(self.buckets_touched)``, regardless of how
        many distinct request sizes were seen."""
        return self._jit._cache_size()

    # -- output batch-axis discovery ----------------------------------------
    def _discover_axes(self, batch) -> None:
        n1, n2 = 2, 5  # delta of 3: a coincidental non-batch match is ~impossible
        key = jax.random.PRNGKey(0)
        small = jax.tree.map(lambda x: x[:1], batch)
        # Boot call: run fn once EAGERLY on concrete arrays before any trace.
        # Lazily-initialized artifacts (e.g. an AutoGuide warm-started from a
        # checkpoint that has never been called) set up their prototype here
        # with concrete values; doing it under eval_shape/jit would leak
        # tracers into that cached state.
        probe = lambda k, b: self._call_fn(k, b, self.state)
        probe(key, pad_leading(small, n1))
        o1 = jax.eval_shape(probe, key, pad_leading(small, n1))
        if self._explicit_axes is not None:
            # explicit axes skip discovery entirely (the escape hatch for
            # outputs where discovery is ambiguous)
            if not isinstance(o1, dict):
                raise ValueError("out_batch_axes requires a flat dict output")
            self._axes = [self._explicit_axes.get(k) for k in sorted(o1)]
            return
        o2 = jax.eval_shape(probe, key, pad_leading(small, n2))
        f1 = jax.tree_util.tree_leaves(o1)
        f2 = jax.tree_util.tree_leaves(o2)
        axes = []
        for path_leaf, (s1, s2) in zip(
            jax.tree_util.tree_flatten_with_path(o1)[0], zip(f1, f2)
        ):
            diffs = [
                i for i, (a, b) in enumerate(zip(s1.shape, s2.shape)) if a != b
            ]
            if not diffs:
                axes.append(None)
            elif len(diffs) == 1 and s2.shape[diffs[0]] - s1.shape[diffs[0]] == n2 - n1:
                axes.append(diffs[0])
            else:
                name = "/".join(str(p) for p in path_leaf[0])
                raise ValueError(
                    f"cannot infer the batch axis of output leaf '{name}' "
                    f"({s1.shape} at batch {n1} vs {s2.shape} at batch {n2}); "
                    f"pass out_batch_axes explicitly"
                )
        self._axes = axes

    def slice_output(self, out: Any, start: int, stop: int) -> Any:
        """Slice ``[start, stop)`` of the request-batch axis out of every
        batch-bearing leaf; global leaves pass through whole. Used by the
        engine to strip padding and by the micro-batcher to scatter one
        coalesced forward back to its requests."""
        flat, treedef = jax.tree_util.tree_flatten(out)
        if self._axes is None or len(self._axes) != len(flat):
            raise RuntimeError("slice_output before the first __call__")
        sliced = [
            leaf if ax is None else jax.lax.slice_in_dim(leaf, start, stop, axis=ax)
            for leaf, ax in zip(flat, self._axes)
        ]
        return jax.tree_util.tree_unflatten(treedef, sliced)

    # -- serving entry point -------------------------------------------------
    def __call__(self, rng_key, batch):
        n = batch_count(batch)
        b = bucket_for(n, self.buckets)
        if self._axes is None:
            self._discover_axes(batch)
        padded = pad_leading(batch, b, force_copy=self.donate)
        out = self._jit(rng_key, padded, self.state if self._has_state else ())
        self.buckets_touched.add(b)
        return self.slice_output(out, 0, n)
