"""Posterior-serving subsystem: trained inference artifacts (SVI guides,
MCMC sample stores, enumerated decoders) as compiled, batched, mesh-sharded
endpoints. See docs/serving.md for the artifact -> endpoint walkthrough."""
from .batcher import MicroBatcher, ServeStats
from .engine import CompiledServable, bucket_for, default_buckets
from .registry import (
    ServableModel,
    clear_registry,
    get_servable,
    list_servables,
    register,
    unregister,
)

__all__ = [
    "CompiledServable",
    "MicroBatcher",
    "ServableModel",
    "ServeStats",
    "bucket_for",
    "clear_registry",
    "default_buckets",
    "get_servable",
    "list_servables",
    "register",
    "unregister",
]
