"""Posterior-serving subsystem: trained inference artifacts (SVI guides,
MCMC sample stores, enumerated decoders) as compiled, batched, mesh-sharded
endpoints. See docs/serving.md for the artifact -> endpoint walkthrough."""
from .batcher import LoadShedError, MicroBatcher, ServeStats
from .engine import CompiledServable, bucket_for, default_buckets
from .registry import (
    ServableModel,
    clear_registry,
    get_servable,
    list_servables,
    register,
    unregister,
)
from .server import InferenceServer
from .trainer import StreamingTrainer, hot_swap_on_commit

__all__ = [
    "CompiledServable",
    "InferenceServer",
    "LoadShedError",
    "MicroBatcher",
    "ServableModel",
    "ServeStats",
    "StreamingTrainer",
    "bucket_for",
    "clear_registry",
    "default_buckets",
    "get_servable",
    "hot_swap_on_commit",
    "list_servables",
    "register",
    "unregister",
]
