"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, end_lr: float = 0.0):
    def schedule(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        frac = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = end_lr + 0.5 * (peak_lr - end_lr) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule


def warmup_rsqrt(peak_lr: float, warmup_steps: int):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        decay = peak_lr * jnp.sqrt(warmup_steps / jnp.maximum(step, warmup_steps))
        return jnp.where(step < warmup_steps, warm, decay)

    return schedule


def exponential_decay(init_lr: float, decay_rate: float, decay_steps: int):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        return init_lr * decay_rate ** (step / decay_steps)

    return schedule
