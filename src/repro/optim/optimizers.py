"""Optimizers built from scratch (no optax in this environment).

Pure-functional: `init(params) -> state`, `update(grads, state) -> state`,
`get_params(state) -> params`. States are pytrees, so they pjit-shard exactly
like the parameters they track (DESIGN.md §6: optimizer moments inherit the
FSDP+TP sharding of their parameters).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def _as_schedule(lr: Union[float, Schedule]) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


class OptState(NamedTuple):
    step: jax.Array
    params: Any
    mu: Any  # first moment (or momentum); None-like zeros when unused
    nu: Any  # second moment


class Optimizer:
    """Base class; subclasses define `_update_leaf`."""

    def __init__(
        self,
        learning_rate: Union[float, Schedule] = 1e-3,
        clip_norm: Optional[float] = None,
        weight_decay: float = 0.0,
    ):
        self.lr = _as_schedule(learning_rate)
        self.clip_norm = clip_norm
        self.weight_decay = weight_decay

    def init(self, params) -> OptState:
        # mu/nu must be distinct buffers (donation forbids aliased arguments)
        mu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        nu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), params, mu, nu)

    def update(self, grads, state: OptState) -> OptState:
        if self.clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr(step)
        new_params, new_mu, new_nu = {}, {}, {}
        flat_p, treedef = jax.tree_util.tree_flatten(state.params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mu = treedef.flatten_up_to(state.mu)
        flat_nu = treedef.flatten_up_to(state.nu)
        out_p, out_mu, out_nu = [], [], []
        for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
            if self.weight_decay:
                g = g + self.weight_decay * p
            p2, mu2, nu2 = self._update_leaf(step, lr, p, g, mu, nu)
            out_p.append(p2)
            out_mu.append(mu2)
            out_nu.append(nu2)
        return OptState(
            step,
            jax.tree_util.tree_unflatten(treedef, out_p),
            jax.tree_util.tree_unflatten(treedef, out_mu),
            jax.tree_util.tree_unflatten(treedef, out_nu),
        )

    def get_params(self, state: OptState):
        return state.params

    def _update_leaf(self, step, lr, p, g, mu, nu):
        raise NotImplementedError


class SGD(Optimizer):
    def __init__(self, learning_rate=1e-3, momentum: float = 0.0, nesterov: bool = False, **kw):
        super().__init__(learning_rate, **kw)
        self.momentum = momentum
        self.nesterov = nesterov

    def _update_leaf(self, step, lr, p, g, mu, nu):
        if self.momentum == 0.0:
            return p - lr * g, mu, nu
        mu2 = self.momentum * mu + g
        d = g + self.momentum * mu2 if self.nesterov else mu2
        return p - lr * d, mu2, nu


class Adam(Optimizer):
    def __init__(self, learning_rate=1e-3, b1=0.9, b2=0.999, eps=1e-8, **kw):
        super().__init__(learning_rate, **kw)
        self.b1, self.b2, self.eps = b1, b2, eps

    def _update_leaf(self, step, lr, p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu2 = self.b1 * mu + (1 - self.b1) * g32
        nu2 = self.b2 * nu + (1 - self.b2) * jnp.square(g32)
        t = step.astype(jnp.float32)
        mu_hat = mu2 / (1 - self.b1 ** t)
        nu_hat = nu2 / (1 - self.b2 ** t)
        upd = lr * mu_hat / (jnp.sqrt(nu_hat) + self.eps)
        return (p.astype(jnp.float32) - upd).astype(p.dtype), mu2, nu2


class AdamW(Adam):
    """Adam with decoupled weight decay (applied to the update, not the grad)."""

    def _update_leaf(self, step, lr, p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu2 = self.b1 * mu + (1 - self.b1) * g32
        nu2 = self.b2 * nu + (1 - self.b2) * jnp.square(g32)
        t = step.astype(jnp.float32)
        mu_hat = mu2 / (1 - self.b1 ** t)
        nu_hat = nu2 / (1 - self.b2 ** t)
        upd = lr * (mu_hat / (jnp.sqrt(nu_hat) + self.eps) + self.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - upd).astype(p.dtype), mu2, nu2

    def update(self, grads, state: OptState) -> OptState:
        # decay handled in _update_leaf; bypass the grad-coupled decay in base
        if self.clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr(step)
        flat_p, treedef = jax.tree_util.tree_flatten(state.params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mu = treedef.flatten_up_to(state.mu)
        flat_nu = treedef.flatten_up_to(state.nu)
        out = [self._update_leaf(step, lr, p, g, mu, nu) for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
        return OptState(
            step,
            jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
        )


class Adafactor(Optimizer):
    """Memory-factored second-moment optimizer (Shazeer & Stern 2018) —
    the memory-saving choice at 132B scale: O(n+m) state per (n,m) matrix."""

    def __init__(self, learning_rate=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0, **kw):
        super().__init__(learning_rate, **kw)
        self.decay = decay
        self.eps = eps
        self.clip_threshold = clip_threshold

    def init(self, params) -> OptState:
        def row_col(p):
            if p.ndim >= 2:
                return (
                    jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                )
            return (jnp.zeros_like(p, jnp.float32), jnp.zeros((), jnp.float32))

        mu = jax.tree_util.tree_map(lambda p: row_col(p)[0], params)
        nu = jax.tree_util.tree_map(lambda p: row_col(p)[1], params)
        return OptState(jnp.zeros((), jnp.int32), params, mu, nu)

    def _update_leaf(self, step, lr, p, g, row, col):
        g32 = g.astype(jnp.float32)
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-self.decay)
        g2 = jnp.square(g32) + self.eps
        if p.ndim >= 2:
            row2 = beta * row + (1 - beta) * g2.mean(-1)
            col2 = beta * col + (1 - beta) * g2.mean(-2)
            r = row2 / row2.mean(-1, keepdims=True)
            v = r[..., None] * col2[..., None, :]
        else:
            row2 = beta * row + (1 - beta) * g2
            col2 = col
            v = row2
        u = g32 / jnp.sqrt(v)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)))
        u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), row2, col2


class MultiSteps:
    """Gradient accumulation wrapper: apply the inner optimizer every
    `every_k` micro-steps (distributed-opt trick for huge global batches)."""

    def __init__(self, inner: Optimizer, every_k: int):
        self.inner = inner
        self.every_k = every_k

    def init(self, params):
        acc = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return (self.inner.init(params), acc, jnp.zeros((), jnp.int32))

    def update(self, grads, state):
        inner_state, acc, k = state
        acc = jax.tree_util.tree_map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        k = k + 1

        def apply(args):
            inner_state, acc = args
            mean = jax.tree_util.tree_map(lambda a: a / self.every_k, acc)
            new_inner = self.inner.update(mean, inner_state)
            zeros = jax.tree_util.tree_map(jnp.zeros_like, acc)
            return new_inner, zeros

        def skip(args):
            return args

        inner_state, acc = jax.lax.cond(k % self.every_k == 0, apply, skip, (inner_state, acc))
        return (inner_state, acc, k)

    def get_params(self, state):
        return self.inner.get_params(state[0])
