from .optimizers import (
    Adafactor,
    Adam,
    AdamW,
    MultiSteps,
    Optimizer,
    OptState,
    SGD,
    clip_by_global_norm,
    global_norm,
)
from .schedules import constant, exponential_decay, warmup_cosine, warmup_rsqrt

__all__ = [
    "Adafactor",
    "Adam",
    "AdamW",
    "MultiSteps",
    "Optimizer",
    "OptState",
    "SGD",
    "clip_by_global_norm",
    "global_norm",
    "constant",
    "exponential_decay",
    "warmup_cosine",
    "warmup_rsqrt",
]
