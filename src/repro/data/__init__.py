from .pipeline import DataConfig, SyntheticTokens, skip_ahead

__all__ = ["DataConfig", "SyntheticTokens", "skip_ahead"]
