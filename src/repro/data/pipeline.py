"""Deterministic synthetic token pipeline with host-sharded global batches.

Production shape: each host process produces ONLY its local slice of the
global batch (`host_batch_slice`), so the pipeline scales to any number of
data-loading hosts with zero coordination — the (step, host) pair fully
determines the data. Restart/elastic semantics: data for step N is identical
regardless of topology, so checkpoints can resume on a different mesh
without skipping or repeating tokens (DESIGN.md §6).

The generator is a Markov-ish mixture over a synthetic vocabulary with
enough structure that a 135M model's loss visibly drops within hundreds of
steps (used by examples/train_lm.py and the Fig-3 benchmark).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 64  # latent "topics"; lower => easier to model


class SyntheticTokens:
    """step -> {'tokens': (B, S), 'targets': (B, S)} int32, deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # per-pattern unigram tables, concentrated for learnability
        V = min(cfg.vocab, 4096)
        logits = rng.gumbel(size=(cfg.n_patterns, V)) * 2.0
        self._tables = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        self._V = V

    def _sequence(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row])
        )
        pat = rng.integers(cfg.n_patterns)
        table = self._tables[pat]
        toks = rng.choice(self._V, size=cfg.seq_len + 1, p=table)
        # inject a deterministic local structure: every 8th token repeats
        toks[8 :: 8] = toks[7 :: 8][: len(toks[8::8])]
        return toks.astype(np.int32)

    def global_batch(self, step: int) -> Dict[str, jax.Array]:
        rows = np.stack([self._sequence(step, r) for r in range(self.cfg.global_batch)])
        return {
            "tokens": jnp.asarray(rows[:, :-1]),
            "targets": jnp.asarray(rows[:, 1:]),
        }

    def host_batch_slice(self, step: int, host_id: int, n_hosts: int) -> Dict[str, jax.Array]:
        per = self.cfg.global_batch // n_hosts
        rows = np.stack(
            [self._sequence(step, host_id * per + r) for r in range(per)]
        )
        return {
            "tokens": jnp.asarray(rows[:, :-1]),
            "targets": jnp.asarray(rows[:, 1:]),
        }

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.global_batch(step)
            step += 1


class Prefetcher:
    """Host-side prefetch: a background thread pulls batches from a source
    iterator into a bounded queue so the training step never waits on data
    generation (the lm1b input-pipeline idiom — producer thread + bounded
    buffer — without a tf.data dependency).

    The buffer holds at most ``prefetch`` batches, so a slow consumer
    back-pressures the producer instead of growing host memory. Exceptions
    in the source re-raise on the consumer thread at the point of `next`;
    `close()` stops the producer and unblocks it if the queue is full.

        for batch in Prefetcher(stream, prefetch=4):
            state, loss = svi.update_jit(state, batch)
    """

    _DONE = object()

    def __init__(self, source: Iterable, *, prefetch: int = 4):
        if prefetch < 1:
            raise ValueError("prefetch must be >= 1")
        self._source = iter(source)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._closed = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self) -> None:
        try:
            for item in self._source:
                while not self._closed.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._closed.is_set():
                    return
            self._q.put(self._DONE)
        except BaseException as e:  # noqa: BLE001 — re-raised on consumer
            self._q.put(e)

    def __iter__(self) -> "Prefetcher":
        return self

    def __next__(self) -> Any:
        if self._closed.is_set():
            raise StopIteration
        item = self._q.get()
        if item is self._DONE:
            raise StopIteration
        if isinstance(item, BaseException):
            raise item
        return item

    def close(self) -> None:
        """Stop the producer (idempotent); pending batches are dropped."""
        self._closed.set()
        # unblock a producer stuck on a full queue
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


@dataclass(frozen=True)
class RegressionStreamConfig:
    """Synthetic streaming linear-regression source for the streaming
    inference service: `dim` features, `batch` rows per step, true weights
    that *drift* slowly (rotated by `drift` radians per step around the
    first two coordinates) — so a posterior trained on old steps is
    measurably stale and a hot-swapped refresh is observable in served
    predictions."""

    dim: int = 4
    batch: int = 64
    seed: int = 0
    noise: float = 0.1
    drift: float = 0.0


class RegressionStream:
    """step -> {'x': (B, D), 'y': (B,)} float32, deterministic per (cfg, step)."""

    def __init__(self, cfg: RegressionStreamConfig, max_steps: Optional[int] = None):
        self.cfg = cfg
        self.max_steps = max_steps
        rng = np.random.default_rng(cfg.seed)
        self._w0 = rng.normal(size=cfg.dim).astype(np.float32)
        self._b = np.float32(rng.normal())

    def true_weights(self, step: int) -> np.ndarray:
        w = self._w0.copy()
        if self.cfg.drift and self.cfg.dim >= 2:
            theta = self.cfg.drift * step
            c, s = np.cos(theta), np.sin(theta)
            w0, w1 = w[0], w[1]
            w[0], w[1] = c * w0 - s * w1, s * w0 + c * w1
        return w

    def batch(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
        x = rng.normal(size=(cfg.batch, cfg.dim)).astype(np.float32)
        w = self.true_weights(step)
        y = x @ w + self._b + cfg.noise * rng.normal(size=cfg.batch).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y.astype(np.float32))}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while self.max_steps is None or step < self.max_steps:
            yield self.batch(step)
            step += 1


def skip_ahead(it: "SyntheticTokens", to_step: int) -> int:
    """Deterministic skip: nothing to do (stateless), returns the step. Kept
    as an explicit API so a file-backed pipeline can implement real seeking —
    the straggler watchdog uses it to resynchronize a replaced host."""
    return to_step
