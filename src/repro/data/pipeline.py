"""Deterministic synthetic token pipeline with host-sharded global batches.

Production shape: each host process produces ONLY its local slice of the
global batch (`host_batch_slice`), so the pipeline scales to any number of
data-loading hosts with zero coordination — the (step, host) pair fully
determines the data. Restart/elastic semantics: data for step N is identical
regardless of topology, so checkpoints can resume on a different mesh
without skipping or repeating tokens (DESIGN.md §6).

The generator is a Markov-ish mixture over a synthetic vocabulary with
enough structure that a 135M model's loss visibly drops within hundreds of
steps (used by examples/train_lm.py and the Fig-3 benchmark).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 64  # latent "topics"; lower => easier to model


class SyntheticTokens:
    """step -> {'tokens': (B, S), 'targets': (B, S)} int32, deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # per-pattern unigram tables, concentrated for learnability
        V = min(cfg.vocab, 4096)
        logits = rng.gumbel(size=(cfg.n_patterns, V)) * 2.0
        self._tables = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        self._V = V

    def _sequence(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row])
        )
        pat = rng.integers(cfg.n_patterns)
        table = self._tables[pat]
        toks = rng.choice(self._V, size=cfg.seq_len + 1, p=table)
        # inject a deterministic local structure: every 8th token repeats
        toks[8 :: 8] = toks[7 :: 8][: len(toks[8::8])]
        return toks.astype(np.int32)

    def global_batch(self, step: int) -> Dict[str, jax.Array]:
        rows = np.stack([self._sequence(step, r) for r in range(self.cfg.global_batch)])
        return {
            "tokens": jnp.asarray(rows[:, :-1]),
            "targets": jnp.asarray(rows[:, 1:]),
        }

    def host_batch_slice(self, step: int, host_id: int, n_hosts: int) -> Dict[str, jax.Array]:
        per = self.cfg.global_batch // n_hosts
        rows = np.stack(
            [self._sequence(step, host_id * per + r) for r in range(per)]
        )
        return {
            "tokens": jnp.asarray(rows[:, :-1]),
            "targets": jnp.asarray(rows[:, 1:]),
        }

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.global_batch(step)
            step += 1


def skip_ahead(it: "SyntheticTokens", to_step: int) -> int:
    """Deterministic skip: nothing to do (stateless), returns the step. Kept
    as an explicit API so a file-backed pipeline can implement real seeking —
    the straggler watchdog uses it to resynchronize a replaced host."""
    return to_step
