"""Sharding rules: parameter / optimizer / activation PartitionSpecs.

Scheme (DESIGN.md §6) — 2D "FSDP + TP" over mesh axes ("data", "model"),
with an optional leading "pod" axis that extends *data* parallelism across
pods (params replicated across pods; the cross-pod gradient all-reduce is
the only DCN collective per step):

* every weight is sharded on "model" along its TP-parallel dim (heads /
  ffn / experts / vocab) and on "data" along the other large dim (FSDP) —
  XLA SPMD inserts per-layer all-gathers inside the scan (overlapped with
  compute) and reduce-scatters for gradients;
* optimizer moments inherit the param spec (tree_map);
* batch inputs are sharded on ("pod","data") along batch;
* KV caches shard batch on "data" and heads (or head_dim, for small-K GQA /
  MQA / MLA-latent) on "model".

Rules are (regex over param path) -> dims template, resolved against the
actual rank of each leaf (leading scan-stack dims padded with None).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

# (pattern, spec-for-trailing-dims). First match wins. Specs are given for
# the *logical* (unstacked) weight; leading stack dims get None.
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embeddings / head
    (r"embed$", ("model", "data")),          # (V, D): vocab TP + FSDP
    (r"lm_head$", ("data", "model")),        # (D, V)
    (r"final_norm$", (None,)),
    # attention (GQA)
    (r"\bwq$", ("data", "model")),
    (r"\bwk$", ("data", "model")),
    (r"\bwv$", ("data", "model")),
    (r"\bwo$", ("model", "data")),
    (r"\bb[qkv]$", ("model",)),
    (r"[qk]_norm$", (None,)),
    # MLA
    (r"wkv_d$", ("data", None)),             # (D, r+rope): latent dims small
    (r"wk_u$", (None, "model")),             # (r, H*nope)
    (r"wv_u$", (None, "model")),             # (r, H*v)
    # dense / shared-expert MLP
    (r"\bwg$", ("data", "model")),
    (r"\bwu$", ("data", "model")),
    (r"\bwd$", ("model", "data")),
    # MoE (expert parallelism on "model")
    (r"router$", ("data", None)),
    (r"we_g$", ("model", "data", None)),     # (E, D, de)
    (r"we_u$", ("model", "data", None)),
    (r"we_d$", ("model", None, "data")),     # (E, de, D)
    # Mamba-2 SSD
    (r"in_proj$", ("data", "model")),
    (r"out_proj$", ("model", "data")),
    (r"conv_w$", (None, "model")),
    (r"gate_norm$", ("model",)),
    (r"(A_log|D_skip|dt_bias)$", (None,)),
    # RG-LRU
    (r"wx_in$", ("data", "model")),
    (r"wy_in$", ("data", "model")),
    (r"\bwa$", ("model", None, None)),       # (blocks, bw, bw)
    (r"wxg$", ("model", None, None)),
    (r"(ba|bxg|Lambda)$", ("model",)),
    # norms and anything else small
    (r"ln\d$", (None,)),
)


def _path_str(path) -> str:
    return ".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def spec_for_param(path_str: str, ndim: int, mesh: Mesh) -> P:
    """Resolve the rule table for one leaf; pad leading dims with None and
    drop axis names whose dimension would not divide (checked by caller via
    validate_divisibility)."""
    for pat, dims in _RULES:
        if re.search(pat, path_str):
            pad = ndim - len(dims)
            if pad < 0:  # scalar-ish leaf (e.g. rank < template): replicate
                return P()
            return P(*([None] * pad), *dims)
    return P()  # default: replicated


def _divisible(spec: P, shape, mesh: Mesh) -> P:
    """Drop axis assignments that don't divide the dim (e.g. 9 heads on a
    16-way model axis) — correctness first, the dry-run reports what's left."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is None:
            out.append(None)
            continue
        size = mesh.shape[axis] if not isinstance(axis, tuple) else 1
        out.append(axis if dim % size == 0 else None)
    return P(*out)


def _canonical(spec: P) -> P:
    """Strip trailing Nones (P('data', None) == P('data') semantically). GSPMD
    reports jit output shardings in this minimal form; emitting the same form
    here keeps device_put-placed state and jit-returned state cache-identical."""
    dims = list(spec)
    while dims and dims[-1] is None:
        dims.pop()
    return P(*dims)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    """Pytree of NamedShardings matching `params` (works on ShapeDtypeStructs)."""

    def leaf(path, x):
        spec = spec_for_param(_path_str(path), len(x.shape), mesh)
        spec = _divisible(spec, x.shape, mesh)
        return NamedSharding(mesh, _canonical(spec))

    return jax.tree_util.tree_map_with_path(leaf, params)


def batch_axes(mesh: Mesh):
    """The composite data-parallel axis: ('pod','data') when a pod axis
    exists, else 'data'; meshes without a 'data' axis fall back to their
    first axis (so generic SVI meshes work, not just the LM layout)."""
    if "data" not in mesh.axis_names:
        return mesh.axis_names[0]
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def data_axis_size(mesh: Mesh, axis=None) -> int:
    """Total shard count along the composite data axes (or an explicit
    axis/tuple) — the divisor every leading-dim sharding decision checks.
    One implementation shared by SVI batch sharding, MCMC chain sharding,
    activation constraints, and the serving engine's bucket placement."""
    if axis is None:
        axis = batch_axes(mesh)
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return size


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    dp = batch_axes(mesh)
    dp_size = data_axis_size(mesh, dp)

    def leaf(x):
        if not x.shape or x.shape[0] % dp_size != 0:
            return NamedSharding(mesh, P())  # tiny batches replicate
        return NamedSharding(mesh, P(dp, *([None] * (len(x.shape) - 1))))

    return jax.tree.map(leaf, batch)


def cache_shardings(cache: Any, cfg: ModelConfig, mesh: Mesh, *,
                    mla_mode: str = "seq") -> Any:
    """KV / SSM / LRU cache sharding: batch on 'data'; heads or head_dim on
    'model' where divisible. Cache leaves may carry a leading scan-stack dim.

    Layouts seen here (post-stack):
      attention k/v  (..., B, K, S, hd)
      MLA            (..., B, S, r) / (..., B, S, rope)
      ssd state      (..., B, h, n, p);  conv (..., B, w, c)
      rglru h        (..., B, W);        conv (..., B, w, c)
      pos            () scalar
    """
    tp = mesh.shape["model"]

    def leaf(path, x):
        name = _path_str(path)
        shape = x.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        # find the batch dim: first dim whose size matches is ambiguous, so
        # key on structure: k/v rank>=4, others rank>=2; batch dim follows
        # any scan-stack dim. We mark the stack dim by name "scan".
        lead = 1 if name.startswith("scan") else 0
        dims: list = [None] * len(shape)
        if shape[lead] % mesh.shape["data"] == 0:
            dims[lead] = "data"
        if "k" == name.split(".")[-1] or name.split(".")[-1] in ("k", "v"):
            K, hd = shape[lead + 1], shape[-1]
            if K % tp == 0:
                dims[lead + 1] = "model"
            elif hd % tp == 0:
                dims[-1] = "model"
        elif name.endswith("state") or name.endswith("h"):
            if shape[-1] % tp == 0:
                dims[-1] = "model"
        elif name.endswith("c_kv") or name.endswith("k_rope"):
            # MLA latent cache: shard the SEQUENCE axis on 'model' (default).
            # Sharding the latent rank r costs a per-layer scores all-reduce
            # (the baseline, kept under mla_mode="rank"); replicating costs
            # full-cache HBM reads per device (refuted, §Perf iter 3b).
            # Sequence sharding keeps scores and cache reads local.
            if mla_mode == "rank":
                if shape[-1] % tp == 0:
                    dims[-1] = "model"
            elif len(shape) >= 2 and shape[lead + 1] % tp == 0:
                dims[lead + 1] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(leaf, cache)


def constrain_leading_dim(x: Any, mesh: Mesh, axis=None) -> Any:
    """with_sharding_constraint `x`'s leading dim onto `axis` (default: the
    composite data axes). Scalars, non-arrays, and leading dims that don't
    divide the axis size pass through unconstrained (replication is correct,
    just not parallel). Shared by SVI batch sharding and ELBO particle
    sharding so the divisibility/spec logic lives in exactly one place."""
    if axis is None:
        axis = batch_axes(mesh)
    size = data_axis_size(mesh, axis)
    if not hasattr(x, "ndim") or x.ndim == 0 or x.shape[0] % size != 0:
        return x
    spec = P(axis, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_batch(tree: Any, mesh: Mesh) -> Any:
    """Constrain every array leaf's leading (batch) dim onto the data axes —
    the in-jit counterpart of `batch_shardings` for SVI minibatch args."""
    dp = batch_axes(mesh)
    return jax.tree.map(lambda x: constrain_leading_dim(x, mesh, dp), tree)


def default_mesh(axis_name: str = "data") -> Mesh:
    """1-D mesh over all local devices — the default for embarrassingly
    data-parallel workloads (MCMC chain sharding) where no TP axis is needed.
    `batch_axes` resolves it like any other mesh with a 'data' axis."""
    return jax.make_mesh((jax.device_count(),), (axis_name,))


def shard_chains(tree: Any, mesh: Mesh) -> Any:
    """Constrain every array leaf's leading (chain) dim onto the data axes —
    MCMC's counterpart of `shard_batch` (same policy, one implementation).
    Chains whose count doesn't divide the data-axis size pass through
    replicated (correct, just not parallel), so a 4-chain run works
    unchanged on 1, 2 or 4 devices."""
    return shard_batch(tree, mesh)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# activation sharding constraints (trace-time contextvar scope)
# ---------------------------------------------------------------------------
#
# XLA's sharding propagation, left alone, can resolve the FSDP/TP conflict by
# replicating the batch dim and splitting d_model (observed in the smollm
# dry-run). The launcher installs this scope while tracing so the model can
# pin activations to (batch='data', ..., None) without importing the mesh.

import contextlib
import contextvars

_ACT_SCOPE: contextvars.ContextVar[Optional[Tuple[Mesh, Any]]] = contextvars.ContextVar(
    "repro_activation_sharding", default=None
)


@contextlib.contextmanager
def activation_sharding_scope(mesh: Mesh):
    token = _ACT_SCOPE.set((mesh, batch_axes(mesh)))
    try:
        yield
    finally:
        _ACT_SCOPE.reset(token)


def constrain_activation(x: jax.Array, *, extra: Optional[Dict[int, str]] = None) -> jax.Array:
    """Pin dim 0 (batch) to the data axes; optional {dim: axis} extras
    (e.g. {-1: 'model'} for vocab-sharded logits). No-op outside the scope."""
    ctx = _ACT_SCOPE.get()
    if ctx is None:
        return x
    mesh, dp = ctx
    dims = [None] * x.ndim
    dp_size = data_axis_size(mesh, dp)
    if x.shape[0] % dp_size == 0:
        dims[0] = dp
    if extra:
        for d, axis in extra.items():
            if x.shape[d] % mesh.shape[axis] == 0:
                dims[d] = axis
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*dims)))
