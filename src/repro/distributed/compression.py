"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 stochastic-rounding quantization with per-tensor scale: the pod-level
gradient all-reduce crosses the slow DCN link, so shrinking it 4x (f32->i8)
directly shrinks the only cross-pod collective in the step (DESIGN.md §6).
Error feedback (residual carrying) keeps SGD/Adam convergence unbiased-ish
in practice; both knobs are exposed.

Usage inside a pjit'd step:
    g_q, scale = quantize_int8(g, rng)
    g_q = lax.pmean(g_q, 'pod')             # cheap DCN all-reduce
    g = dequantize_int8(g_q, scale)
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, rng: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Stochastic-rounding symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    lo = jnp.floor(y)
    frac = y - lo
    bump = (jax.random.uniform(rng, x.shape) < frac).astype(jnp.float32)
    q = jnp.clip(lo + bump, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_tree(tree: Any, rng: jax.Array) -> Tuple[Any, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    qs, scales = [], []
    for i, leaf in enumerate(leaves):
        q, s = quantize_int8(leaf, jax.random.fold_in(rng, i))
        qs.append(q)
        scales.append(s)
    return (
        jax.tree_util.tree_unflatten(treedef, qs),
        jax.tree_util.tree_unflatten(treedef, scales),
    )


def dequantize_tree(qtree: Any, scales: Any) -> Any:
    return jax.tree.map(dequantize_int8, qtree, scales)


def compress_error_feedback(grads: Any, residual: Any, rng: jax.Array):
    """(grads+residual) -> quantized grads + new residual (the quantization
    error), the standard error-feedback loop for compressed all-reduce."""
    carried = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    q, scales = quantize_tree(carried, rng)
    deq = dequantize_tree(q, scales)
    new_residual = jax.tree.map(lambda c, d: c - d, carried, deq)
    return q, scales, new_residual
