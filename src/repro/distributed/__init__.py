from .compression import (
    compress_error_feedback,
    dequantize_int8,
    dequantize_tree,
    quantize_int8,
    quantize_tree,
)
from .fault_tolerance import HeartbeatRegistry, StepWatchdog, plan_remesh
from .sharding import (
    activation_sharding_scope,
    batch_axes,
    batch_shardings,
    cache_shardings,
    constrain_activation,
    constrain_leading_dim,
    param_shardings,
    replicated,
    shard_batch,
    spec_for_param,
)

__all__ = [
    "HeartbeatRegistry",
    "StepWatchdog",
    "activation_sharding_scope",
    "batch_axes",
    "batch_shardings",
    "cache_shardings",
    "compress_error_feedback",
    "constrain_activation",
    "constrain_leading_dim",
    "dequantize_int8",
    "dequantize_tree",
    "param_shardings",
    "plan_remesh",
    "quantize_int8",
    "quantize_tree",
    "replicated",
    "shard_batch",
    "spec_for_param",
]
