"""Fault tolerance & straggler mitigation (DESIGN.md §6).

What is real here (unit-tested, CPU-runnable):
  * `StepWatchdog` — per-step wall-clock watchdog with EWMA baseline; flags
    stragglers (steps slower than `threshold` x the EWMA) and invokes a
    callback (on a real fleet: trigger checkpoint + spare substitution; in
    examples: log + optional early checkpoint).
  * `HeartbeatRegistry` — host heartbeat table with expiry, the decision
    input for elastic re-meshing.
  * `plan_remesh` — given surviving host count, choose the largest viable
    (data, model) mesh <= survivors and report the reshard plan; combined
    with topology-independent checkpoints (checkpoint/store.py) this is the
    restart path after a node failure.

What is necessarily simulated on one CPU host: actual process loss and ICI
re-routing. The seams (callbacks, registry, plan) are the production API.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class StepWatchdog:
    """EWMA step-time watchdog: `observe(dt)` returns True when the step is
    a straggler (dt > threshold * ewma after warmup)."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1, warmup: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.count = 0
        self.stragglers: List[Tuple[int, float]] = []

    def observe(self, dt: float) -> bool:
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = self.count > self.warmup and dt > self.threshold * self.ewma
        if is_straggler:
            self.stragglers.append((self.count, dt))
            if self.on_straggler:
                self.on_straggler(self.count, dt, self.ewma)
            # do NOT fold stragglers into the baseline
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


@dataclass
class HeartbeatRegistry:
    """Host liveness table (on a fleet: fed by a side channel / GCS)."""

    timeout: float = 60.0
    last_seen: Dict[int, float] = field(default_factory=dict)

    def beat(self, host_id: int, now: Optional[float] = None) -> None:
        self.last_seen[host_id] = time.time() if now is None else now

    def alive(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return sorted(h for h, t in self.last_seen.items() if now - t < self.timeout)

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return sorted(h for h, t in self.last_seen.items() if now - t >= self.timeout)


def plan_remesh(n_hosts_alive: int, chips_per_host: int = 4,
                model_parallelism: int = 16) -> Optional[dict]:
    """Largest viable (data, model) mesh from the surviving chips. Model
    parallelism is kept (weights must fit); data parallelism shrinks to the
    largest power-of-two of remaining chips / model. Returns None if even
    one model replica no longer fits."""
    chips = n_hosts_alive * chips_per_host
    if chips < model_parallelism:
        return None
    data = 1
    while data * 2 * model_parallelism <= chips:
        data *= 2
    return {
        "mesh_shape": (data, model_parallelism),
        "axes": ("data", "model"),
        "chips_used": data * model_parallelism,
        "chips_idle": chips - data * model_parallelism,
        "action": "restore latest checkpoint with new shardings "
                  "(checkpoint.restore(..., shardings=param_shardings(params, new_mesh)))",
    }
