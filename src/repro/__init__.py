"""repro — Deep Universal Probabilistic Programming on JAX/TPU.

A production-scale JAX reimplementation of the Pyro PPL (Bingham et al. 2018):
effect-handler runtime (repro.core), distributions (repro.distributions),
inference (repro.infer), plus the distributed LM training/serving framework
that exercises the PPL at 512-chip scale (repro.models / launch / configs).
"""
from . import core
from .core import (
    deterministic,
    factor,
    module,
    param,
    plate,
    prng_key,
    sample,
    subsample,
)
from .core import handlers

__version__ = "0.1.0"

__all__ = [
    "core",
    "handlers",
    "sample",
    "param",
    "plate",
    "deterministic",
    "factor",
    "module",
    "prng_key",
    "subsample",
]
