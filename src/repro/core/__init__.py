"""repro.core — the paper's contribution: effect-handler PPL runtime."""
from . import handlers, messenger, primitives, reparam as _reparam_mod
from .handlers import Trace
from .reparam import LocScaleReparam, reparam
from .messenger import Messenger, apply_stack
from .primitives import (
    deterministic,
    factor,
    module,
    param,
    plate,
    prng_key,
    sample,
    subsample,
)

__all__ = [
    "handlers",
    "messenger",
    "primitives",
    "Messenger",
    "Trace",
    "LocScaleReparam",
    "reparam",
    "apply_stack",
    "sample",
    "param",
    "plate",
    "deterministic",
    "factor",
    "module",
    "prng_key",
    "subsample",
]
