"""repro.core — the paper's contribution: effect-handler PPL runtime."""
from . import handlers, messenger, primitives, reparam as _reparam_mod
from .handlers import Trace, config, config_enumerate, config_gaussian, enum, infer_config
from .reparam import LocScaleReparam, reparam
from .messenger import DimAllocator, Messenger, apply_stack
from .primitives import (
    deterministic,
    factor,
    module,
    param,
    plate,
    prng_key,
    sample,
    subsample,
)

__all__ = [
    "handlers",
    "messenger",
    "primitives",
    "DimAllocator",
    "Messenger",
    "Trace",
    "LocScaleReparam",
    "reparam",
    "apply_stack",
    "config",
    "config_enumerate",
    "config_gaussian",
    "enum",
    "infer_config",
    "sample",
    "param",
    "plate",
    "deterministic",
    "factor",
    "module",
    "prng_key",
    "subsample",
]
