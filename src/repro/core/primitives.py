"""The two language primitives of the paper (§2): `sample` and `param` —
plus the standard derived primitives (`plate`, `deterministic`, `factor`,
`module`, `prng_key`, `subsample`).
"""
from __future__ import annotations

from collections import namedtuple
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..distributions import Delta, Distribution, Unit, constraints
from ..distributions.wrappers import ExpandedDistribution
from .messenger import Messenger, am_i_wrapped, apply_stack, make_message

CondIndepStackFrame = namedtuple("CondIndepStackFrame", ["name", "dim", "size", "subsample_size"])


def sample(
    name: str,
    fn: Distribution,
    obs: Optional[Any] = None,
    rng_key: Optional[jax.Array] = None,
    sample_shape: tuple = (),
    infer: Optional[dict] = None,
) -> Any:
    """Annotate a call to a stochastic function. `obs=` conditions the site
    (the paper's mechanism for expressing unnormalized joint densities)."""
    if not am_i_wrapped():
        # outside any handler: behave like the raw distribution
        if obs is not None:
            return obs
        if rng_key is None:
            raise RuntimeError(
                f"sample('{name}') outside an inference context requires rng_key="
            )
        return fn.sample(rng_key, sample_shape)
    msg = make_message(
        "sample",
        name,
        fn=fn,
        kwargs={"rng_key": rng_key, "sample_shape": sample_shape},
        value=obs,
        is_observed=obs is not None,
        infer=infer,
    )
    apply_stack(msg)
    return msg["value"]


def param(
    name: str,
    init_value: Any = None,
    constraint: constraints.Constraint = constraints.real,
    event_dim: Optional[int] = None,
) -> Any:
    """Register a learnable parameter. In this functional JAX port the *value*
    is supplied by a `substitute`/`trace` handler; `init_value` (array or
    callable key->array) seeds initialization."""
    if not am_i_wrapped():
        if callable(init_value) and not hasattr(init_value, "shape"):
            return init_value(None)
        return init_value
    msg = make_message(
        "param",
        name,
        args=(init_value,),
        kwargs={"constraint": constraint, "event_dim": event_dim},
    )
    apply_stack(msg)
    return msg["value"]


def deterministic(name: str, value: Any) -> Any:
    """Record a deterministic function of other sites in the trace."""
    if not am_i_wrapped():
        return value
    msg = make_message("deterministic", name, value=value)
    msg["fn"] = Delta(value, event_dim=jnp.ndim(value))
    msg["is_observed"] = True
    apply_stack(msg)
    return msg["value"]


def factor(name: str, log_factor: Any) -> None:
    """Add an arbitrary log-density term (unnormalized models, paper §2)."""
    unit = Unit(log_factor)
    sample(name, unit, obs=jnp.empty(unit.shape()))


def prng_key() -> Optional[jax.Array]:
    """Draw a fresh PRNG key from the innermost seed handler."""
    if not am_i_wrapped():
        return None
    msg = make_message("prng_key", "_prng_key")
    apply_stack(msg)
    return msg["value"]


def module(name: str, params: dict, constraint=constraints.real) -> dict:
    """Register every leaf of a parameter pytree (Pyro's `pyro.module` for
    torch.nn.Module, adapted to functional pytrees)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        site = name + "." + ".".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append(param(site, leaf, constraint=constraint))
    return jax.tree_util.tree_unflatten(treedef, out)


class plate(Messenger):
    """Conditional-independence context (vectorized). Inside a `plate`, sample
    sites are batched along `dim` and their log_prob is scaled by
    size/subsample_size — Pyro's minibatch-subsampling semantics (paper §2).
    """

    def __init__(
        self,
        name: str,
        size: int,
        subsample_size: Optional[int] = None,
        dim: Optional[int] = None,
    ):
        if size <= 0:
            raise ValueError(f"plate '{name}' needs positive size, got {size}")
        self.name = name
        self.size = size
        self.subsample_size = size if subsample_size is None else subsample_size
        if dim is not None and dim >= 0:
            raise ValueError("plate dim must be negative (batch dims count from the right)")
        self.dim = dim
        self._indices = None
        super().__init__()

    # -- subsample indices are themselves an effect (so `seed` can key them) --
    def _subsample(self):
        msg = make_message(
            "plate",
            self.name,
            args=(self.size, self.subsample_size),
            kwargs={"rng_key": None},
        )
        apply_stack(msg)
        idx = msg["value"]
        if idx is not None and jnp.shape(idx) != (self.subsample_size,):
            raise ValueError(
                f"plate '{self.name}' got subsample indices of shape "
                f"{jnp.shape(idx)}; expected ({self.subsample_size},)"
            )
        return idx

    def __enter__(self):
        super().__enter__()
        try:
            self._indices = self._subsample()
            if self.dim is None:
                # allocate the innermost free dim not used by enclosing plates
                used = {
                    f.dim
                    for h in _enclosing_plates(self)
                    for f in [h.frame]
                }
                d = -1
                while d in used:
                    d -= 1
                self.dim = d
            self.frame = CondIndepStackFrame(self.name, self.dim, self.size, self.subsample_size)
        except Exception:
            # un-push self so a failed __enter__ (bad indices, missing rng
            # key) can't leak a half-initialized handler on the global stack
            super().__exit__(None, None, None)
            raise
        return self._indices

    @property
    def indices(self):
        return self._indices

    def process_message(self, msg):
        if msg["type"] not in ("sample", "deterministic", "param"):
            return
        if msg["type"] == "sample":
            msg["cond_indep_stack"] = (self.frame,) + msg["cond_indep_stack"]
            # broadcast the distribution along the plate dim
            fn = msg["fn"]
            if isinstance(fn, Distribution):
                batch_shape = list(fn.batch_shape)
                # target position of the plate dim within the batch shape
                needed = -self.dim
                while len(batch_shape) < needed:
                    batch_shape.insert(0, 1)
                if batch_shape[self.dim] != self.subsample_size:
                    if batch_shape[self.dim] not in (1, self.subsample_size):
                        raise ValueError(
                            f"shape mismatch at site '{msg['name']}' inside plate "
                            f"'{self.name}': dim {self.dim} has size {batch_shape[self.dim]},"
                            f" expected {self.subsample_size}"
                        )
                    batch_shape[self.dim] = self.subsample_size
                    msg["fn"] = ExpandedDistribution(fn, tuple(batch_shape))
                elif tuple(batch_shape) != fn.batch_shape:
                    msg["fn"] = ExpandedDistribution(fn, tuple(batch_shape))
        if self.subsample_size < self.size:
            scale = self.size / self.subsample_size
            msg["scale"] = scale if msg["scale"] is None else msg["scale"] * scale


def _enclosing_plates(me):
    from .messenger import current_stack

    return [h for h in current_stack() if isinstance(h, plate) and h is not me and hasattr(h, "frame")]


def subsample(data: jax.Array, event_dim: int = 0) -> jax.Array:
    """Subsample `data` along the innermost active plate dims (Pyro's
    `pyro.subsample`)."""
    from .messenger import current_stack

    for h in current_stack():
        if isinstance(h, plate) and hasattr(h, "frame") and h.subsample_size < h.size:
            dim = h.frame.dim - event_dim
            axis = data.ndim + dim
            data = jnp.take(data, h.indices, axis=axis)
    return data
