"""Poutine: the algebraic effect-handler stack (paper §2, Kammar et al. 2013).

This is the paper's key architectural contribution: `sample`/`param`
primitives raise *messages* that climb a stack of Messenger handlers; each
handler may read or rewrite the message. Inference algorithms are compositions
of small handlers, cleanly separated from models and from the runtime.

JAX adaptation (DESIGN.md §2): handlers run **at trace time**. Under
`jax.jit`, the whole handler stack executes while XLA traces the function, so
the compiled program contains zero PPL overhead — the paper's Fig-3 overhead
experiment becomes a *trace-time* cost here, amortized across all executions
of the compiled step.
"""
from __future__ import annotations

import functools
import threading
from typing import Any, Callable, Dict, List, Optional


class _HandlerStacks(threading.local):
    """Per-thread handler stack. The streaming service traces models from
    several threads at once (the background trainer's SVI step, each
    MicroBatcher worker compiling a fresh bucket); a process-global stack
    would interleave their handlers and corrupt both traces (symptom:
    spurious "duplicate site name" errors under concurrent load). Entering
    a Messenger pushes onto the *calling thread's* stack; index 0 is the
    outermost handler, the last element is the innermost."""

    def __init__(self):
        self.stack: List["Messenger"] = []


_LOCAL = _HandlerStacks()


def current_stack() -> List["Messenger"]:
    """The calling thread's handler stack (mutable, thread-local)."""
    return _LOCAL.stack


def am_i_wrapped() -> bool:
    return len(current_stack()) > 0


def default_process_message(msg: Dict[str, Any]) -> None:
    """Default effect: actually sample / return the param init value."""
    if msg["value"] is None:
        if msg["type"] == "sample":
            fn = msg["fn"]
            key = msg["kwargs"].get("rng_key")
            if key is None:
                raise RuntimeError(
                    f"sample site '{msg['name']}' needs an rng key: wrap the call "
                    "in repro.handlers.seed(fn, rng_key) or pass rng_key= explicitly."
                )
            sample_shape = msg["kwargs"].get("sample_shape", ())
            value, intermediates = fn.sample_with_intermediates(key, sample_shape)
            msg["value"] = value
            msg["intermediates"] = intermediates
        elif msg["type"] == "param":
            init = msg["args"][0] if msg["args"] else None
            if callable(init) and not hasattr(init, "shape"):
                key = msg["kwargs"].get("rng_key")
                msg["value"] = init(key) if key is not None else init(None)
            else:
                msg["value"] = init
        elif msg["type"] == "plate":
            import jax.numpy as jnp

            size = msg["args"][0]
            subsample_size = msg["args"][1]
            if subsample_size is None or subsample_size == size:
                msg["value"] = jnp.arange(size)
            else:
                key = msg["kwargs"].get("rng_key")
                if key is None:
                    raise RuntimeError(
                        f"subsampling plate '{msg['name']}' needs an rng key: "
                        "wrap in repro.handlers.seed."
                    )
                import jax

                msg["value"] = jax.random.choice(
                    key, size, shape=(subsample_size,), replace=False
                )


def apply_stack(msg: Dict[str, Any]) -> Dict[str, Any]:
    """Run a message up the handler stack (innermost first), apply the default
    behavior unless a handler provided a value or stopped propagation, then run
    postprocessing back down the stack (Pyro's apply_stack semantics)."""
    stack = current_stack()
    pointer = 0
    for pointer, handler in enumerate(reversed(stack)):
        handler.process_message(msg)
        if msg.get("stop"):
            break
    default_process_message(msg)
    for handler in stack[len(stack) - pointer - 1 :]:
        handler.postprocess_message(msg)
    return msg


class DimAllocator:
    """Allocates fresh negative batch dims for parallel enumeration, growing
    leftward from `first_available_dim` (which must sit left of every plate
    dim, i.e. ``first_available_dim <= -1 - max_plate_nesting``). One
    allocator lives per `enum` handler entry, so dim assignment is a pure
    function of site execution order — jit-stable across steps."""

    def __init__(self, first_available_dim: int):
        if first_available_dim >= 0:
            raise ValueError(
                f"first_available_dim must be negative (batch dims count from "
                f"the right), got {first_available_dim}"
            )
        self._next = first_available_dim
        self.allocated: Dict[str, int] = {}

    def allocate(self, name: str) -> int:
        dim = self._next
        self._next -= 1
        self.allocated[name] = dim
        return dim


class Messenger:
    """Base effect handler: a context manager + callable wrapper."""

    def __init__(self, fn: Optional[Callable] = None):
        self.fn = fn
        functools.update_wrapper(self, fn, updated=[]) if fn is not None else None

    def __enter__(self):
        current_stack().append(self)
        return self

    def __exit__(self, exc_type, exc_value, traceback):
        # remove self even if handlers above us leaked (exception safety);
        # enter/exit always pair on one thread, so this sees the same stack
        stack = current_stack()
        if stack and stack[-1] is self:
            stack.pop()
        else:  # pragma: no cover - defensive
            stack.remove(self)

    def process_message(self, msg: Dict[str, Any]) -> None:
        pass

    def postprocess_message(self, msg: Dict[str, Any]) -> None:
        pass

    def __call__(self, *args, **kwargs):
        if self.fn is None:
            raise TypeError(f"{type(self).__name__} wraps no function; use as a context manager")
        with self:
            return self.fn(*args, **kwargs)


def make_message(
    msg_type: str,
    name: str,
    fn: Any = None,
    args: tuple = (),
    kwargs: Optional[dict] = None,
    value: Any = None,
    is_observed: bool = False,
    infer: Optional[dict] = None,
) -> Dict[str, Any]:
    return {
        "type": msg_type,
        "name": name,
        "fn": fn,
        "args": args,
        "kwargs": kwargs or {},
        "value": value,
        "is_observed": is_observed,
        "scale": None,  # multiplicative log_prob scale (plate subsampling / handlers.scale)
        "mask": None,  # boolean mask applied to log_prob
        "cond_indep_stack": (),  # active plates
        "intermediates": [],
        # copy: handlers (enum) write per-site keys into msg["infer"], and the
        # caller may share one annotation dict across sites
        "infer": dict(infer) if infer else {},
        "stop": False,
        "done": False,
    }
