"""Reparameterization handler (Pyro's `poutine.reparam`): rewrite a sample
site into an equivalent, better-conditioned form at trace time.

`LocScaleReparam` decenters loc-scale families — the classic fix for
funnel-shaped posteriors (Neal's funnel) in both SVI and HMC:

    x ~ Normal(mu, sigma)        becomes
    x_decentered ~ Normal(0, 1);  x = deterministic(mu + sigma * x_dec)
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from ..distributions import Delta, Normal
from .messenger import Messenger
from . import primitives


class LocScaleReparam:
    """Decentering of a Normal site: x = loc + scale * z, z ~ N(0, 1)."""

    def __call__(self, name: str, fn) -> jnp.ndarray:
        if not isinstance(fn, Normal):
            raise ValueError(f"LocScaleReparam expects Normal at '{name}'")
        z = primitives.sample(
            f"{name}_decentered",
            Normal(jnp.zeros_like(fn.loc), jnp.ones_like(fn.scale)),
        )
        return fn.loc + fn.scale * z


class reparam(Messenger):
    """Handler: config maps site name -> reparameterizer."""

    def __init__(self, fn=None, config: Optional[Dict[str, LocScaleReparam]] = None):
        self.config = config or {}
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] != "sample" or msg["is_observed"]:
            return
        name = msg["name"]
        if name not in self.config or msg.get("_reparam_done"):
            return
        strategy = self.config[name]
        value = strategy(name, msg["fn"])
        msg["value"] = value
        msg["fn"] = Delta(value, event_dim=len(msg["fn"].event_shape))
        # the site is now a deterministic function of the auxiliary site:
        # mark observed so guides don't try to (re)sample it and its Delta
        # contributes zero density at its own point
        msg["is_observed"] = True
        msg["_reparam_done"] = True
