"""The Poutine handler library (paper §2): trace, replay, seed, condition,
substitute, block, mask, scale, lift, do, reparam-free subset of Pyro's
poutine. Every inference algorithm in repro.infer is a composition of these.
"""
from __future__ import annotations

import hashlib
import warnings
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from ..distributions import Delta, Distribution
from .messenger import DimAllocator, Messenger


def _site_key_int(name: str) -> int:
    """Stable 31-bit hash of a site name — used to fold per-site randomness
    out of a single seed key, making sampling order-independent."""
    return int.from_bytes(hashlib.sha1(name.encode()).digest()[:4], "little") & 0x7FFFFFFF


# ---------------------------------------------------------------------------
# Trace data structure
# ---------------------------------------------------------------------------


class Trace:
    """An execution trace: ordered map site name -> message."""

    def __init__(self):
        self.nodes: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()

    def add_node(self, name: str, msg: Dict[str, Any]) -> None:
        if name in self.nodes:
            raise RuntimeError(f"duplicate site name '{name}' in a single execution")
        self.nodes[name] = msg

    def __iter__(self):
        return iter(self.nodes.values())

    def __getitem__(self, name):
        return self.nodes[name]

    def __contains__(self, name):
        return name in self.nodes

    def copy(self) -> "Trace":
        t = Trace()
        t.nodes = OrderedDict((k, dict(v)) for k, v in self.nodes.items())
        return t

    # -- log-density computation ------------------------------------------
    def compute_log_prob(self, site_filter: Callable[[str, dict], bool] = lambda n, s: True):
        for name, site in self.nodes.items():
            if site["type"] == "sample" and site_filter(name, site):
                if "log_prob" not in site:
                    lp = site["fn"].log_prob(site["value"])
                    if site["mask"] is not None:
                        lp = jnp.where(site["mask"], lp, 0.0)
                    if site["scale"] is not None:
                        lp = lp * site["scale"]
                    site["log_prob"] = lp
        return self

    def log_prob_sum(self, site_filter: Callable[[str, dict], bool] = lambda n, s: True):
        self.compute_log_prob(site_filter)
        total = 0.0
        for name, site in self.nodes.items():
            if site["type"] == "sample" and site_filter(name, site) and "log_prob" in site:
                total = total + jnp.sum(site["log_prob"])
        return total

    # convenience views
    def stochastic_nodes(self):
        return [n for n, s in self.nodes.items() if s["type"] == "sample" and not s["is_observed"]]

    def observed_nodes(self):
        return [n for n, s in self.nodes.items() if s["type"] == "sample" and s["is_observed"]]

    def param_nodes(self):
        return [n for n, s in self.nodes.items() if s["type"] == "param"]


# ---------------------------------------------------------------------------
# Handlers
# ---------------------------------------------------------------------------


class trace(Messenger):
    """Record every effect into a Trace."""

    def __enter__(self):
        super().__enter__()
        self.trace = Trace()
        return self.trace

    def postprocess_message(self, msg):
        if msg["type"] in ("sample", "param", "deterministic", "plate"):
            self.trace.add_node(msg["name"], dict(msg))

    def get_trace(self, *args, **kwargs) -> Trace:
        with self as tr:
            self.fn(*args, **kwargs)
        return tr


class replay(Messenger):
    """Force sample sites to take the values recorded in `guide_trace`
    (the mechanism by which ELBOs run the model at guide samples)."""

    def __init__(self, fn=None, guide_trace: Optional[Trace] = None):
        if guide_trace is None:
            raise ValueError("replay needs a guide_trace")
        self.guide_trace = guide_trace
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] == "sample" and msg["name"] in self.guide_trace.nodes:
            guide_msg = self.guide_trace.nodes[msg["name"]]
            if guide_msg["type"] != "sample" or guide_msg["is_observed"]:
                raise RuntimeError(f"site '{msg['name']}' must be a latent sample in the guide")
            msg["value"] = guide_msg["value"]
            msg["infer"] = {**guide_msg["infer"], **msg["infer"]}


class seed(Messenger):
    """Thread an explicit PRNG key. Per-site keys are fold_in(key, sha1(name))
    so models are reproducible and site-order independent (DESIGN.md §2)."""

    def __init__(self, fn=None, rng_seed: Union[int, jax.Array, None] = None):
        if rng_seed is None:
            raise ValueError("seed needs rng_seed (int or PRNG key)")
        if isinstance(rng_seed, int) or (
            hasattr(rng_seed, "dtype") and jnp.issubdtype(rng_seed.dtype, jnp.integer) and jnp.ndim(rng_seed) == 0
        ):
            rng_seed = jax.random.PRNGKey(rng_seed)
        self.rng_key = rng_seed
        self._counter = 0
        super().__init__(fn)

    def __enter__(self):
        self._counter = 0
        return super().__enter__()

    def process_message(self, msg):
        if (
            msg["type"] in ("sample", "plate")
            and not msg["is_observed"]
            and msg["value"] is None
            and msg["kwargs"].get("rng_key") is None
        ):
            msg["kwargs"]["rng_key"] = jax.random.fold_in(
                self.rng_key, _site_key_int(msg["name"])
            )
        elif msg["type"] == "param" and msg["kwargs"].get("rng_key") is None:
            msg["kwargs"]["rng_key"] = jax.random.fold_in(
                self.rng_key, _site_key_int("$param$" + msg["name"])
            )
        elif msg["type"] == "prng_key" and msg["value"] is None:
            self._counter += 1
            msg["value"] = jax.random.fold_in(
                self.rng_key, _site_key_int(f"$prng_key${self._counter}")
            )


class substitute(Messenger):
    """Fix sample/param sites to given values (by dict or by function).
    This is how optimizers inject current parameter values each step.
    `data` entries keyed by a plate name fix that plate's subsample indices —
    the mechanism by which SVI.update accepts minibatch indices as part of
    its pure (jit-stable) signature."""

    def __init__(self, fn=None, data: Optional[Dict[str, Any]] = None, substitute_fn=None):
        if (data is None) == (substitute_fn is None):
            raise ValueError("pass exactly one of data / substitute_fn")
        self.data = data
        self.substitute_fn = substitute_fn
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] not in ("sample", "param", "plate"):
            return
        if msg["value"] is not None:
            return
        if self.data is not None:
            if msg["name"] in self.data:
                msg["value"] = self.data[msg["name"]]
        else:
            value = self.substitute_fn(msg)
            if value is not None:
                msg["value"] = value


class condition(Messenger):
    """Condition sample sites on observed values (paper Fig. 1:
    `pyro.condition(model, data={"x": x})`)."""

    def __init__(self, fn=None, data: Optional[Dict[str, Any]] = None):
        self.data = data or {}
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] == "sample" and msg["name"] in self.data:
            msg["value"] = self.data[msg["name"]]
            msg["is_observed"] = True


class do(Messenger):
    """Causal intervention: sever the site from its parents, fixing its value
    without adding a log-density contribution."""

    def __init__(self, fn=None, data: Optional[Dict[str, Any]] = None):
        self.data = data or {}
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] == "sample" and msg["name"] in self.data:
            msg["value"] = jnp.asarray(self.data[msg["name"]])
            msg["is_observed"] = False
            msg["intervened"] = True
            msg["stop"] = True
            msg["fn"] = Delta(msg["value"], event_dim=len(msg["fn"].event_shape))


class block(Messenger):
    """Hide selected sites from outer handlers."""

    def __init__(self, fn=None, hide_fn=None, hide=None, expose=None, expose_types=None):
        if hide_fn is not None:
            self.hide_fn = hide_fn
        elif hide is not None:
            self.hide_fn = lambda msg: msg["name"] in hide
        elif expose is not None:
            self.hide_fn = lambda msg: msg["name"] not in expose
        elif expose_types is not None:
            self.hide_fn = lambda msg: msg["type"] not in expose_types
        else:
            self.hide_fn = lambda msg: True
        super().__init__(fn)

    def process_message(self, msg):
        if self.hide_fn(msg):
            msg["stop"] = True


class mask(Messenger):
    """Multiply downstream log_probs by a boolean mask (variable-length
    sequences — the DMM uses this for padded mini-batches)."""

    def __init__(self, fn=None, mask=None):
        if mask is None:
            raise ValueError("mask handler needs mask=")
        self._mask = mask
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] != "sample":
            return
        msg["mask"] = self._mask if msg["mask"] is None else msg["mask"] & self._mask


class scale(Messenger):
    """Rescale downstream log_probs (minibatch N/B correction, annealing)."""

    def __init__(self, fn=None, scale=1.0):
        self._scale = scale
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] == "sample":
            msg["scale"] = self._scale if msg["scale"] is None else msg["scale"] * self._scale


class lift(Messenger):
    """Lift param sites to sample sites drawn from a prior — Bayesian NNs
    from deterministic ones (used by the Bayesian-last-layer LM option)."""

    def __init__(self, fn=None, prior=None):
        if prior is None:
            raise ValueError("lift needs prior= (Distribution or dict name->Distribution)")
        self.prior = prior
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] != "param":
            return
        prior = self.prior
        if isinstance(prior, dict):
            if msg["name"] not in prior:
                return
            prior = prior[msg["name"]]
        msg["type"] = "sample"
        msg["fn"] = prior
        msg["args"] = ()
        msg["is_observed"] = False
        msg["kwargs"] = {"rng_key": msg["kwargs"].get("rng_key"), "sample_shape": ()}


class infer_config(Messenger):
    """Fill in `infer` annotations on sample sites via a config function
    (Pyro's poutine.infer_config). Explicit per-site annotations win."""

    def __init__(self, fn=None, config_fn: Optional[Callable] = None):
        if config_fn is None:
            raise ValueError("infer_config needs config_fn=")
        self.config_fn = config_fn
        super().__init__(fn)

    def process_message(self, msg):
        if msg["type"] == "sample":
            extra = self.config_fn(msg)
            if extra:
                msg["infer"] = {**extra, **msg["infer"]}


def _enumerate_config_fn(strategy: str, site_set):
    """Annotate discrete non-observed sites with ``{"enumerate": strategy}``
    unless already annotated (explicit per-site annotations win)."""

    def config_fn(msg):
        if msg["is_observed"] or not getattr(msg["fn"], "is_discrete", False):
            return {}
        if "enumerate" in msg["infer"]:
            return {}
        if site_set is not None and msg["name"] not in site_set:
            return {}
        return {"enumerate": strategy}

    return config_fn


def _gaussian_config_fn(site_set):
    """Annotate Normal/MVN non-observed sites with
    ``{"marginalize": "gaussian"}``; naming a non-Gaussian site raises at
    trace time."""

    def config_fn(msg):
        # local import: distributions imports core for its sample machinery
        from ..distributions.continuous import MultivariateNormal, Normal

        if msg["is_observed"] or "marginalize" in msg["infer"]:
            return {}
        if site_set is not None and msg["name"] not in site_set:
            return {}
        if not isinstance(msg["fn"], (Normal, MultivariateNormal)):
            if site_set is not None:
                raise ValueError(
                    f"config: site '{msg['name']}' has distribution "
                    f"{type(msg['fn']).__name__}; only Normal and "
                    "MultivariateNormal sites can be Gaussian-marginalized"
                )
            return {}
        return {"marginalize": "gaussian"}

    return config_fn


def config(fn=None, *, enumerate=None, marginalize=None, sites=None,
           config_fn=None):
    """The one annotation surface for inference configuration: wrap a model
    so its sample sites carry the ``infer`` annotations the engines read.
    Subsumes `config_enumerate`, `config_gaussian`, and raw `infer_config`
    (all three remain as deprecated aliases of this function).

    Arguments (any combination; at least one must be given):

    * ``enumerate`` — ``True`` or a strategy name (only ``"parallel"`` is
      implemented): annotate discrete non-observed sites with
      ``infer={"enumerate": "parallel"}`` so `TraceEnum_ELBO` /
      `infer_discrete` sum them out exactly.
    * ``marginalize`` — ``True`` or ``"gaussian"``: annotate Normal/MVN
      non-observed sites with ``infer={"marginalize": "gaussian"}`` so the
      Gaussian semiring integrates them out exactly.
    * ``sites`` — restrict either annotation to these site names. Naming a
      non-Gaussian site under ``marginalize`` raises at trace time.
    * ``config_fn`` — escape hatch: an arbitrary ``msg -> dict`` callable,
      applied after the declarative annotations (explicit per-site
      annotations still win over everything).

    Usable as a wrapper or a decorator::

        model = config(model, enumerate=True)
        model = config(model, enumerate=True, marginalize="gaussian")  # SLDS
        @config(marginalize="gaussian", sites=["x0"])
        def model(...): ...
    """
    if fn is None:  # decorator-with-arguments form
        return lambda f: config(f, enumerate=enumerate, marginalize=marginalize,
                                sites=sites, config_fn=config_fn)
    if enumerate is None and marginalize is None and config_fn is None:
        raise ValueError(
            "config() needs at least one of enumerate=, marginalize=, "
            "or config_fn="
        )
    if enumerate is True:
        enumerate = "parallel"
    if enumerate is not None and enumerate not in ("parallel",):
        raise NotImplementedError(
            f"enumerate strategy '{enumerate}' is not supported; only "
            "'parallel' (broadcast) enumeration is implemented"
        )
    if marginalize is True:
        marginalize = "gaussian"
    if marginalize is not None and marginalize not in ("gaussian",):
        raise NotImplementedError(
            f"marginalize strategy '{marginalize}' is not supported; only "
            "'gaussian' (information-form VE) is implemented"
        )
    site_set = None if sites is None else frozenset(sites)

    fns = []
    if enumerate is not None:
        fns.append(_enumerate_config_fn(enumerate, site_set))
    if marginalize is not None:
        fns.append(_gaussian_config_fn(site_set))
    if config_fn is not None:
        fns.append(config_fn)

    def merged(msg):
        out = {}
        for f in fns:
            extra = f(msg)
            if extra:
                out.update(extra)
        return out

    return infer_config(fn, config_fn=merged)


def _warn_alias(old: str, hint: str) -> None:
    # FutureWarning, not DeprecationWarning: the audience is users running
    # model code, and Python hides DeprecationWarning from library frames.
    # The default warning filter shows it once per call site.
    warnings.warn(
        f"{old} is deprecated; use {hint} instead (see docs/enumeration.md).",
        FutureWarning,
        stacklevel=3,
    )


def config_enumerate(fn=None, default: str = "parallel"):
    """Deprecated alias of ``config(fn, enumerate=default)``."""
    _warn_alias("config_enumerate(fn)", "config(fn, enumerate=True)")
    if fn is None:  # decorator-with-arguments form
        return lambda f: config(f, enumerate=default)
    return config(fn, enumerate=default)


def config_gaussian(fn=None, sites=None):
    """Deprecated alias of ``config(fn, marginalize="gaussian", sites=sites)``."""
    _warn_alias("config_gaussian(fn)", 'config(fn, marginalize="gaussian")')
    if fn is None:  # decorator-with-arguments form
        return lambda f: config(f, marginalize="gaussian", sites=sites)
    return config(fn, marginalize="gaussian", sites=sites)


class enum(Messenger):
    """Parallel enumeration (paper §2's canonical custom-inference example):
    each discrete sample site annotated with ``infer={"enumerate":
    "parallel"}`` takes its whole finite support as value, broadcast along a
    fresh negative batch dim allocated LEFT of every plate dim. Downstream
    log_probs then carry the enum dims, and `TraceEnum_ELBO` sum-contracts
    them out of the joint (exact marginalization, fully vectorized)."""

    def __init__(self, fn=None, first_available_dim: int = -1):
        self.first_available_dim = first_available_dim
        super().__init__(fn)

    def __enter__(self):
        self._allocator = DimAllocator(self.first_available_dim)
        return super().__enter__()

    def process_message(self, msg):
        if msg["type"] != "sample" or msg["is_observed"] or msg["value"] is not None:
            return
        strategy = msg["infer"].get("enumerate")
        if not strategy:
            return
        if strategy != "parallel":
            raise NotImplementedError(
                f"site '{msg['name']}': enumerate strategy '{strategy}' is not "
                "supported; use 'parallel'"
            )
        fn = msg["fn"]
        support = fn.enumerate_support(expand=False)  # (K,) + (1,)*batch + event
        dim = self._allocator.allocate(msg["name"])
        if -dim - 1 < len(fn.batch_shape):
            raise ValueError(
                f"cannot enumerate site '{msg['name']}': enum dim {dim} collides "
                f"with its batch dims {fn.batch_shape}; pass a more negative "
                "first_available_dim (raise max_plate_nesting)"
            )
        k = support.shape[0]
        msg["value"] = support.reshape((k,) + (1,) * (-dim - 1) + fn.event_shape)
        msg["infer"]["_enumerate_dim"] = dim
        msg["infer"]["_enumerate_cardinality"] = k


class collect_params(Messenger):
    """Collect every `param` site's (value, constraint) without altering the
    execution — used by SVI init to build the optimizer pytree."""

    def __enter__(self):
        super().__enter__()
        self.params: Dict[str, Any] = {}
        self.constraints: Dict[str, Any] = {}
        return self

    def postprocess_message(self, msg):
        if msg["type"] == "param":
            self.params[msg["name"]] = msg["value"]
            self.constraints[msg["name"]] = msg["kwargs"].get("constraint")


# functional conveniences mirroring pyro.poutine.* ---------------------------


def trace_fn(fn):
    return trace(fn)


def replay_fn(fn, guide_trace):
    return replay(fn, guide_trace=guide_trace)
