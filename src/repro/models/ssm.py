"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: within a chunk of length Q the output is a masked
quadratic form (runs on the MXU); across chunks a cheap state recurrence
carries (nheads, headdim, dstate) states. `kernels/ssd_scan.py` provides the
Pallas TPU version of the chunk kernel; this module is the reference path and
the layer plumbing (projections, conv, gating).

Decode mode carries a constant-size recurrent state — this is why mamba2
is a `long_500k`-capable architecture.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init, rmsnorm

Params = Dict[str, Any]


def init_ssd(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [z (di), x (di), B (ns), C (ns), dt (nh)]
        "in_proj": _dense_init(ks[0], (D, 2 * di + 2 * ns + nh), dt),
        "conv_w": _dense_init(ks[1], (cfg.conv_width, di + 2 * ns), dt, scale=0.5),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) in (-inf,0)
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dt),
        "out_proj": _dense_init(ks[2], (di, D), dt),
    }


def ssd_reference(x, dt, A, B, C, chunk: int):
    """Pure-jnp chunked SSD: x (b,s,h,p), dt (b,s,h), A (h,), B/C (b,s,n).
    Returns y (b,s,h,p). Matches the Mamba-2 SSD recurrence:
        h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t h_t
    computed chunk-parallel (intra-chunk quadratic + inter-chunk scan)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = chunk
    nc = s // Q
    xc = x.reshape(b, nc, Q, h, p)
    dtc = dt.reshape(b, nc, Q, h)
    Bc = B.reshape(b, nc, Q, n)
    Cc = C.reshape(b, nc, Q, n)

    dA = dtc * A  # (b,nc,Q,h), negative
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay

    # --- intra-chunk (quadratic, MXU-friendly) ---
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q,Q,h)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    M = CB[..., None] * L  # (b,nc,Q,Q,h)
    y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", M, dtc, xc.astype(jnp.float32))

    # --- chunk states ---
    # state contribution of chunk c: sum_k exp(cum_Q - cum_k) dt_k B_k x_k
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,Q,h)
    states = jnp.einsum(
        "bckn,bckh,bckhp->bchnp",
        Bc.astype(jnp.float32), (dtc * decay_to_end), xc.astype(jnp.float32),
    )  # (b,nc,h,n,p)

    # --- inter-chunk recurrence over nc ---
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # (b,nc,h)

    def step(carry, inp):
        st, dec = inp  # (b,h,n,p), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros_like(states[:, 0])
    _, prev_states = jax.lax.scan(
        step,
        init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    prev_states = prev_states.swapaxes(0, 1)  # (b,nc,h,n,p): state at chunk start

    # --- inter-chunk output: y_inter_q = C_q exp(cum_q) h_chunkstart ---
    in_decay = jnp.exp(cum)  # (b,nc,Q,h)
    y_inter = jnp.einsum(
        "bcqn,bcqh,bchnp->bcqhp", Cc.astype(jnp.float32), in_decay, prev_states
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y


def _ssd_inner(cfg, x, dt, A, B, C):
    if cfg.use_pallas:
        from ..kernels.ops import ssd_scan

        return ssd_scan(x, dt, A, B, C, chunk=cfg.ssm_chunk)
    return ssd_reference(x, dt, A, B, C, cfg.ssm_chunk)


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (B,S,C), w: (width,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return out


def ssd_block(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    mode: str = "train",
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba-2 block: in_proj -> conv -> SSD -> gated norm -> out_proj.
    decode carries {'state': (B,h,n,p), 'conv': (B,width-1,di+2ns)}."""
    B_, S, D = x.shape
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"], preferred_element_type=jnp.float32)
    z = zxbcdt[..., :di].astype(x.dtype)
    xbc = zxbcdt[..., di : di + di + 2 * ns].astype(x.dtype)
    dt_raw = zxbcdt[..., -nh:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B,S,nh) f32
    A = -jnp.exp(p["A_log"])  # (nh,)

    if mode == "decode":
        assert cache is not None and S == 1
        conv_buf = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,width,di+2ns)
        xbc_c = jax.nn.silu(
            jnp.sum(conv_buf * p["conv_w"][None], axis=1, keepdims=True).astype(jnp.float32)
        ).astype(x.dtype)
        xs = xbc_c[..., :di].reshape(B_, 1, nh, hp)
        Bmat = xbc_c[..., di : di + ns]
        Cmat = xbc_c[..., di + ns :]
        dec = jnp.exp(dt[:, 0] * A)  # (B,nh)
        state = cache["state"] * dec[..., None, None] + jnp.einsum(
            "bn,bh,bhp->bhnp", Bmat[:, 0].astype(jnp.float32), dt[:, 0],
            xs[:, 0].astype(jnp.float32),
        )
        y = jnp.einsum("bn,bhnp->bhp", Cmat[:, 0].astype(jnp.float32), state)
        y = y[:, None] + p["D_skip"][None, None, :, None] * xs.astype(jnp.float32)
        new_cache = {"state": state, "conv": conv_buf[:, 1:]}
    else:
        xbc_c = jax.nn.silu(_causal_conv(xbc, p["conv_w"]).astype(jnp.float32)).astype(x.dtype)
        xs = xbc_c[..., :di].reshape(B_, S, nh, hp)
        Bmat = xbc_c[..., di : di + ns]
        Cmat = xbc_c[..., di + ns :]
        # pad S to a chunk multiple: dt=0 tail entries have decay exp(0)=1 and
        # zero input, so they alter neither outputs nor the carried state
        pad = (-S) % cfg.ssm_chunk
        if pad:
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B_p = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
            C_p = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
            y = _ssd_inner(cfg, xs_p, dt_p, A, B_p, C_p)[:, :S]
        else:
            y = _ssd_inner(cfg, xs, dt, A, Bmat, Cmat)
        y = y + p["D_skip"][None, None, :, None] * xs.astype(jnp.float32)
        if mode == "prefill":
            # final state for subsequent decode (recompute via recurrence tail)
            decay_all = jnp.exp(jnp.cumsum(dt * A, axis=1))  # (B,S,nh)
            w = decay_all[:, -1:] / decay_all  # decay from t to end
            state = jnp.einsum(
                "bsn,bsh,bshp->bhnp",
                Bmat.astype(jnp.float32), dt * w, xs.astype(jnp.float32),
            )
            new_cache = {
                "state": state,
                "conv": jnp.pad(xbc, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))[
                    :, -(cfg.conv_width - 1) :
                ],
            }
        else:
            new_cache = None

    y = y.reshape(B_, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["gate_norm"])
    out = jnp.einsum("bsd,dk->bsk", y, p["out_proj"], preferred_element_type=jnp.float32)
    return out.astype(x.dtype), new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di, ns = cfg.d_inner, cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.ssm_nheads, ns, cfg.ssm_headdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * ns), dtype),
    }
