from .config import ModelConfig
from .lm import (
    forward,
    init_cache,
    init_params,
    lm_program,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    nll_loss,
)

__all__ = [
    "ModelConfig",
    "forward",
    "init_cache",
    "init_params",
    "lm_program",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
    "nll_loss",
]
