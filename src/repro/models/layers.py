"""Transformer building blocks shared by all 10 assigned architectures.

Pure functions over explicit parameter pytrees (functional JAX style). The
probabilistic-program wrapper (`lm.py`) registers these pytrees as `param`
sites via `core.primitives.module`, so the same code serves both the raw-JAX
baseline (Fig-3 comparison) and the PPL training path.

Conventions
-----------
* Weights are stored (in_dim, out_dim); activations are (B, S, D).
* All matmuls run in `cfg.compute_dtype` with float32 accumulation
  (`preferred_element_type`), softmax/norms in float32.
* `mode` is one of "train" | "prefill" | "decode". decode takes a cache and a
  scalar position; train/prefill process a full sequence causally.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    sin, cos = sin[:, :, None, :], cos[:, :, None, :]  # broadcast over heads
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA / MQA / local-window), full-sequence and single-token decode
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    D, H = cfg.d_model, cfg.n_heads
    K = cfg.n_kv_heads or H
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), dt),
        "wk": _dense_init(ks[1], (D, K * hd), dt),
        "wv": _dense_init(ks[2], (D, K * hd), dt),
        "wo": _dense_init(ks[3], (H * hd, D), dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_sdpa(q, k, v, causal: bool = True, window: Optional[int] = None,
               block_q: int = 1024):
    """Flash-style attention with a recompute-in-backward custom VJP.

    Forward == `_sdpa_blockwise`; backward recomputes each q-block's probs
    from (q, k, v, lse) instead of saving the (Sq, Skv) probs tensor — the
    §Perf hillclimb change that removes the dominant HBM term of the train
    cells (XLA otherwise stacks per-block f32 probs across the layer scan).
    q: (B,Hq,Sq,hd); k/v: (B,Hkv,Skv,hd_v). Returns (B,Hq,Sq,hd_v).
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, window, block_q)
    return out


def _flash_mask(iq, bq, Skv, causal, window):
    q_pos = iq * bq + jnp.arange(bq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((bq, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    return mask


def _flash_fwd_impl(q, k, v, causal, window, block_q):
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(block_q, Sq)
    nq = Sq // bq
    qg = q.reshape(B, Hkv, g, nq, bq, hd)
    scale = 1.0 / (hd ** 0.5)

    def body(_, iq_qb):
        iq, qb = iq_qb
        s = jnp.einsum("bkgqh,bksh->bkgqs", qb, k,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_flash_mask(iq, bq, Skv, causal, window)[None, None, None],
                      s, -1e30)
        lse = jax.scipy.special.logsumexp(s, axis=-1)  # (b,k,g,bq)
        p = jnp.exp(s - lse[..., None])
        ob = jnp.einsum("bkgqs,bksh->bkgqh", p.astype(v.dtype), v)
        return None, (ob, lse)

    _, (o, lse) = jax.lax.scan(body, None, (jnp.arange(nq), qg.transpose(3, 0, 1, 2, 4, 5)))
    o = o.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq, v.shape[-1])
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, Hq, Sq)
    return o, lse


def _flash_fwd(q, k, v, causal, window, block_q):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, block_q)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, block_q, res, dout):
    q, k, v, out, lse = res
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(block_q, Sq)
    nq = Sq // bq
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(B, Hkv, g, nq, bq, hd).transpose(3, 0, 1, 2, 4, 5)
    og = out.reshape(B, Hkv, g, nq, bq, -1).transpose(3, 0, 1, 2, 4, 5)
    dog = dout.reshape(B, Hkv, g, nq, bq, -1).transpose(3, 0, 1, 2, 4, 5)
    lseg = lse.reshape(B, Hkv, g, nq, bq).transpose(3, 0, 1, 2, 4)

    def body(carry, xs):
        dk_acc, dv_acc = carry
        iq, qb, ob, dob, lseb = xs
        s = jnp.einsum("bkgqh,bksh->bkgqs", qb, k,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_flash_mask(iq, bq, Skv, causal, window)[None, None, None],
                      s, -1e30)
        p = jnp.exp(s - lseb[..., None])  # recomputed probs (bq, Skv)
        dp = jnp.einsum("bkgqh,bksh->bkgqs", dob.astype(jnp.float32),
                        v.astype(jnp.float32))
        delta = jnp.sum(dob.astype(jnp.float32) * ob.astype(jnp.float32), -1)
        ds = p * (dp - delta[..., None]) * scale
        dqb = jnp.einsum("bkgqs,bksh->bkgqh", ds, k.astype(jnp.float32))
        dk_acc = dk_acc + jnp.einsum("bkgqs,bkgqh->bksh", ds, qb.astype(jnp.float32))
        dv_acc = dv_acc + jnp.einsum("bkgqs,bkgqh->bksh", p, dob.astype(jnp.float32))
        return (dk_acc, dv_acc), dqb

    zeros_k = jnp.zeros(k.shape, jnp.float32)
    zeros_v = jnp.zeros(v.shape, jnp.float32)
    (dk, dv), dqg = jax.lax.scan(
        body, (zeros_k, zeros_v), (jnp.arange(nq), qg, og, dog, lseg)
    )
    dq = dqg.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_sdpa.defvjp(_flash_fwd, _flash_bwd)


def _sdpa_blockwise(q, k, v, *, causal: bool, window: Optional[int], block_q: int = 1024):
    """Memory-bounded attention for long sequences: lax.scan over q blocks so
    no (Sq, Skv) tensor is ever materialized (the jnp analogue of the Pallas
    flash kernel — same roofline shape, XLA-lowered). q: (B,Hq,Sq,hd)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(block_q, Sq)
    nq = Sq // bq
    qg = q.reshape(B, Hkv, g, nq, bq, hd)
    kv_pos = jnp.arange(Skv)[None, :]

    def body(_, iq_qblk):
        iq, qb = iq_qblk  # qb: (B,Hkv,g,bq,hd)
        s = jnp.einsum("bkgqh,bksh->bkgqs", qb, k,
                       preferred_element_type=jnp.float32) / (hd ** 0.5)
        q_pos = iq * bq + jnp.arange(bq)[:, None]
        mask = jnp.ones((bq, Skv), bool)
        if causal:
            mask &= kv_pos <= q_pos
        if window is not None:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        ob = jnp.einsum("bkgqs,bksh->bkgqh", p.astype(v.dtype), v)
        return None, ob

    _, o = jax.lax.scan(body, None, (jnp.arange(nq), qg.transpose(3, 0, 1, 2, 4, 5)))
    o = o.transpose(1, 2, 3, 0, 4, 5)  # (B,Hkv,g,nq,bq,hd_v)
    return o.reshape(B, Hq, Sq, v.shape[-1])


def _sdpa(q, k, v, *, causal: bool, window: Optional[int], q_offset, kv_len_valid=None):
    """q: (B, Hq, Sq, hd), k/v: (B, Hkv, Skv, hd). GQA by head-group einsum.
    q_offset: absolute position of q[0] (0 for train/prefill, pos for decode).
    kv_len_valid: number of valid cache entries (decode with static cache)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    groups = Hq // Hkv
    q = q.reshape(B, Hkv, groups, Sq, hd)
    scores = jnp.einsum(
        "bkgqh,bksh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) / (hd ** 0.5)
    q_pos = q_offset + jnp.arange(Sq)[:, None]  # (Sq, 1)
    kv_pos = jnp.arange(Skv)[None, :]  # (1, Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    if kv_len_valid is not None:
        mask &= kv_pos < kv_len_valid
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs.astype(v.dtype), v)
    return out.reshape(B, Hq, Sq, hd)


def attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str = "train",
    cache: Optional[Dict[str, jax.Array]] = None,
    window: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B, S, D). Returns (out, new_cache)."""
    B, S, D = x.shape
    H = cfg.n_heads
    K = cfg.n_kv_heads or H
    hd = cfg.resolved_head_dim

    q = jnp.einsum("bsd,dk->bsk", x, p["wq"], preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"], preferred_element_type=jnp.float32)
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd).astype(x.dtype)
    k = k.reshape(B, S, K, hd).astype(x.dtype)
    v = v.reshape(B, S, K, hd).astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))  # (B, H, S, hd)

    if mode == "decode":
        assert cache is not None and S == 1
        pos = positions[0, 0]  # scalar decode position
        L = cache["k"].shape[2]
        # ring buffer: windowed layers keep only the last `window` entries;
        # full-attention caches have L >= max position so slot == pos.
        slot = pos % L
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=2)
        # every cached entry is already <= pos and > pos - window, so no
        # positional mask is needed beyond validity (softmax is permutation-
        # invariant over the kv axis; RoPE was applied pre-cache).
        out = _sdpa(q, ck, cv, causal=False, window=None,
                    q_offset=pos, kv_len_valid=jnp.minimum(pos + 1, L))
        new_cache = {"k": ck, "v": cv}
    else:
        if cfg.use_pallas and window is None and S >= 128:
            from ..kernels.ops import flash_attention

            out = flash_attention(q, k, v, causal=True)
        elif S >= 2048:
            # long sequences: flash path — never materializes (S, S) in fwd
            # and recomputes probs in bwd (custom VJP); 'blockwise' keeps
            # XLA's default VJP (saves probs) as the baseline
            bq = 256 if S >= 16384 else 1024
            if cfg.attn_impl == "flash":
                out = flash_sdpa(q, k, v, True, window, bq)
            else:
                out = _sdpa_blockwise(q, k, v, causal=True, window=window, block_q=bq)
        else:
            out = _sdpa(q, k, v, causal=True, window=window, q_offset=0)
        if mode == "prefill":
            if cache is not None:
                # write into the preallocated (possibly larger / ring) buffer
                L = cache["k"].shape[2]
                if L >= S:
                    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=2)
                    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=2)
                else:  # windowed ring: keep last L entries at slot p % L
                    ck = jnp.roll(k[:, :, -L:], (S - L) % L, axis=2)
                    cv = jnp.roll(v[:, :, -L:], (S - L) % L, axis=2)
                new_cache = {"k": ck, "v": cv}
            else:
                new_cache = {"k": k, "v": v}
        else:
            new_cache = None
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    out = jnp.einsum("bsk,kd->bsd", out, p["wo"], preferred_element_type=jnp.float32)
    return out.astype(x.dtype), new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    K = cfg.n_kv_heads or cfg.n_heads
    hd = cfg.resolved_head_dim
    shape = (batch, K, max_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2); compressed KV cache
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> Params:
    D, H = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return {
        "wq": _dense_init(ks[0], (D, H * qd), dt),
        "wkv_d": _dense_init(ks[1], (D, r + cfg.qk_rope_dim), dt),
        "wk_u": _dense_init(ks[2], (r, H * cfg.qk_nope_dim), dt),
        "wv_u": _dense_init(ks[3], (r, H * cfg.v_head_dim), dt),
        "wo": _dense_init(ks[4], (H * cfg.v_head_dim, D), dt),
    }


def mla_attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str = "train",
    cache: Optional[Dict[str, jax.Array]] = None,
    absorb: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """DeepSeek-V2 MLA. The KV cache stores only the rank-`r` latent `c_kv`
    plus the shared rope key (the paper's memory saving).  `absorb=True` uses
    the weight-absorbed decode formulation (scores computed in latent space —
    never materializing per-head K/V), the optimization DeepSeek describe for
    inference; `absorb=False` materializes K/V (train/prefill path)."""
    B, S, D = x.shape
    H = cfg.n_heads
    r, nd, rd, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    q = jnp.einsum("bsd,dk->bsk", x, p["wq"], preferred_element_type=jnp.float32)
    q = q.reshape(B, S, H, nd + rd).astype(x.dtype)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dk->bsk", x, p["wkv_d"], preferred_element_type=jnp.float32)
    c_kv, k_rope = kv[..., :r].astype(x.dtype), kv[..., r:].astype(x.dtype)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]  # shared head

    if mode == "decode":
        assert cache is not None and S == 1
        pos = positions[0, 0]
        c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, pos, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, pos, axis=1)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        kv_valid = pos + 1
        q_offset = pos
    else:
        if mode == "prefill":
            if cache is not None:
                cc = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, 0, axis=1)
                cr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, 0, axis=1)
                new_cache = {"c_kv": cc, "k_rope": cr}
            else:
                new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        else:
            new_cache = None
        kv_valid = None
        q_offset = 0

    Skv = c_kv.shape[1]
    scale = 1.0 / ((nd + rd) ** 0.5)
    if absorb:
        # fold W_uk into q: q_lat (B,S,H,r) = q_nope @ W_uk^T(per head)
        wk_u = p["wk_u"].reshape(r, H, nd)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk_u, preferred_element_type=jnp.float32)
        scores = jnp.einsum("bshr,btr->bhst", q_lat.astype(x.dtype), c_kv,
                            preferred_element_type=jnp.float32)
        scores = scores + jnp.einsum(
            "bshd,btd->bhst", q_rope, k_rope, preferred_element_type=jnp.float32
        )
        scores = scores * scale
        scores = _mask_scores(scores, S, Skv, q_offset, kv_valid)
        probs = jax.nn.softmax(scores, axis=-1)
        # out in latent space, then up-project with W_uv folded into output
        o_lat = jnp.einsum("bhst,btr->bshr", probs.astype(x.dtype), c_kv)
        wv_u = p["wv_u"].reshape(r, H, vd)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, wv_u, preferred_element_type=jnp.float32)
    else:
        k_nope = jnp.einsum("btr,rk->btk", c_kv, p["wk_u"],
                            preferred_element_type=jnp.float32).reshape(B, Skv, H, nd)
        v = jnp.einsum("btr,rk->btk", c_kv, p["wv_u"],
                       preferred_element_type=jnp.float32).reshape(B, Skv, H, vd)
        k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (B, Skv, H, rd))
        if S >= 2048:
            # long sequences: fold [nope|rope] into one head dim and use the
            # blockwise path (scale = 1/sqrt(nd+rd) matches MLA's)
            q_cat = jnp.concatenate([q_nope, q_rope], -1).transpose(0, 2, 1, 3)
            k_cat = jnp.concatenate(
                [k_nope.astype(x.dtype), k_rope_b.astype(x.dtype)], -1
            ).transpose(0, 2, 1, 3)
            bq = 256 if S >= 16384 else 1024
            vt = v.astype(x.dtype).transpose(0, 2, 1, 3)
            if cfg.attn_impl == "flash":
                out = flash_sdpa(q_cat, k_cat, vt, True, None, bq)
            else:
                out = _sdpa_blockwise(q_cat, k_cat, vt, causal=True, window=None, block_q=bq)
            out = out.transpose(0, 2, 1, 3)  # (B,S,H,vd)
        else:
            scores = (
                jnp.einsum("bshn,bthn->bhst", q_nope.astype(jnp.float32), k_nope)
                + jnp.einsum("bshd,bthd->bhst", q_rope.astype(jnp.float32), k_rope_b)
            ) * scale
            scores = _mask_scores(scores, S, Skv, q_offset, kv_valid)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhst,bthv->bshv", probs, v)
    out = out.reshape(B, S, H * vd).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", out, p["wo"], preferred_element_type=jnp.float32)
    return out.astype(x.dtype), new_cache


def _mask_scores(scores, Sq, Skv, q_offset, kv_valid):
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = kv_pos <= q_pos
    if kv_valid is not None:
        mask &= kv_pos < kv_valid
    return jnp.where(mask[None, None], scores, -1e30)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense_init(ks[0], (D, F), dt),
        "wu": _dense_init(ks[1], (D, F), dt),
        "wd": _dense_init(ks[2], (F, D), dt),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["wg"], preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,df->bsf", x, p["wu"], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, p["wd"], preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> Params:
    D, E = cfg.d_model, cfg.n_experts
    de = cfg.d_expert or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (D, E), jnp.float32),
        "we_g": _dense_init(ks[1], (E, D, de), dt),
        "we_u": _dense_init(ks[2], (E, D, de), dt),
        "we_d": _dense_init(ks[3], (E, de, D), dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.n_shared_experts * de)
    return p


def _router_topk(p: Params, cfg: ModelConfig, x: jax.Array):
    """Returns (weights (..., k) normalized, idx (..., k) int32, aux_loss)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # load-balancing auxiliary loss (Switch/GShard form): E * sum_e f_e * P_e
    E = cfg.n_experts
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(idx.reshape(-1, cfg.top_k), E).sum(-2) > 0).astype(jnp.float32),
        axis=0,
    ) / cfg.top_k
    aux = E * jnp.sum(me * ce)
    return weights, idx, aux


def moe_einsum(p: Params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """GShard-style capacity-bucketed dispatch via one-hot einsums — the
    pjit-friendly baseline: XLA SPMD turns the (g,e,c,d) einsums into
    all-to-alls when experts are sharded on the `model` axis.

    Tokens are re-grouped into groups of `cfg.moe_group` so the dispatch
    one-hot is O(T * k * cf * group) — independent of the global batch
    (GShard's G×S grouping; group == tokens-per-data-shard scale)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    Sg = min(cfg.moe_group, T)
    G = T // Sg
    assert G * Sg == T, f"moe_group {Sg} must divide token count {T}"
    cap = max(int(cfg.capacity_factor * k * Sg / E), 1)
    weights, idx, aux = _router_topk(p, cfg, x)  # (B,S,k)

    xg = x.reshape(G, Sg, D)
    weights = weights.reshape(G, Sg, k)
    idx = idx.reshape(G, Sg, k)

    # position of each (token, k) within its chosen expert's bucket
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G,Sg,k,E)
    flat = onehot.reshape(G, Sg * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # (G, Sg*k, E): slots before me
    pos = jnp.einsum("gte,gte->gt", pos, flat).reshape(G, Sg, k)  # my slot
    keep = pos < cap  # overflow tokens dropped (capacity semantics)
    w = weights * keep

    # dispatch one-hot: (G, Sg, k, E) x slot-one-hot (G, Sg, k, cap)
    slot = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot.astype(x.dtype), slot)
    xin = jnp.einsum("gsec,gsd->gecd", dispatch, xg)  # (G,E,cap,D)
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", xin, p["we_g"], preferred_element_type=jnp.float32)
    ) * jnp.einsum("gecd,edf->gecf", xin, p["we_u"], preferred_element_type=jnp.float32)
    out_e = jnp.einsum(
        "gecf,efd->gecd", h.astype(x.dtype), p["we_d"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot.astype(x.dtype), slot, w.astype(x.dtype))
    out = jnp.einsum("gsec,gecd->gsd", combine, out_e).reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x)
    return out, aux


def moe_sort(p: Params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dropless sort-based MoE using `jax.lax.ragged_dot` (MegaBlocks-on-TPU
    style, cf. MaxText 'megablox') — the optimized path: no capacity waste,
    no (e,c) one-hot tensors; grouped GEMM over expert-sorted tokens."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    weights, idx, aux = _router_topk(p, cfg, x)
    xf = x.reshape(T, D)
    eid = idx.reshape(T * k)
    wid = weights.reshape(T * k).astype(x.dtype)
    tok = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(eid)  # stable
    eid_s, tok_s, w_s = eid[order], tok[order], wid[order]
    xin = xf[tok_s]  # (T*k, D) gathered
    group_sizes = jnp.bincount(eid_s, length=E).astype(jnp.int32)

    g = jax.lax.ragged_dot(xin, p["we_g"], group_sizes)
    u = jax.lax.ragged_dot(xin, p["we_u"], group_sizes)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    out_s = jax.lax.ragged_dot(h, p["we_d"], group_sizes)  # (T*k, D)
    out = jnp.zeros((T, D), out_s.dtype).at[tok_s].add(out_s * w_s[:, None])
    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x)
    return out, aux


def moe(p: Params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe_impl == "sort":
        return moe_sort(p, cfg, x)
    return moe_einsum(p, cfg, x)
