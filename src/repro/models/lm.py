"""Unified decoder LM over all assigned architecture families, written as a
probabilistic program (the paper's technique as a first-class feature).

Structure
---------
* `init_params(cfg, key)`  — pure parameter initialization (pytree).
* `forward(cfg, params, tokens_or_embeds, ...)` — pure forward; scan-over-
  repeating-units keeps HLO size O(1) in depth; per-layer remat optional.
* `lm_program(cfg)`        — probabilistic program: registers params as
  `param` sites (pyro.module semantics) and observes tokens through a
  `sample("obs", Categorical(logits), obs=...)` site under a batch `plate`.
  SVI with no latent sites == maximum-likelihood training; `lift` the head
  to get a Bayesian last layer.
* `train_step` / `prefill_step` / `decode_step` builders for the launcher.

Layer layout: layers are grouped into repeating *units* (`cfg.pattern`,
length 1 for uniform archs). Unit parameters are stacked along a leading
`n_units` axis and consumed by `lax.scan`; `L % len(pattern)` leftover
layers are unrolled as the `tail`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as LL
from . import rglru as RG
from . import ssm as SSD

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# per-layer dispatch
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, kind: str) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"ln1": jnp.zeros((cfg.d_model,), dt)}
    if kind == "ssd":
        p["core"] = SSD.init_ssd(k1, cfg)
        return p  # mamba blocks: single norm, no separate mlp
    if kind == "rglru":
        p["core"] = RG.init_rglru(k1, cfg)
    elif kind == "attn":
        p["core"] = LL.init_mla(k1, cfg) if cfg.mla else LL.init_attention(k1, cfg)
    else:  # pragma: no cover
        raise ValueError(kind)
    p["ln2"] = jnp.zeros((cfg.d_model,), dt)
    p["mlp"] = LL.init_moe(k2, cfg) if cfg.moe else LL.init_mlp(k3, cfg)
    return p


def _apply_layer(
    p: Params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    mode: str,
    cache: Optional[Dict],
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = LL.rmsnorm(x, p["ln1"])
    if kind == "ssd":
        y, new_cache = SSD.ssd_block(p["core"], cfg, h, mode=mode, cache=cache)
        return x + y, new_cache, aux
    if kind == "rglru":
        y, new_cache = RG.rglru_block(p["core"], cfg, h, mode=mode, cache=cache)
    elif cfg.mla:
        y, new_cache = LL.mla_attention(
            p["core"], cfg, h, positions, mode=mode, cache=cache,
            absorb=(mode == "decode"),
        )
    else:
        y, new_cache = LL.attention(
            p["core"], cfg, h, positions, mode=mode, cache=cache, window=cfg.window
        )
    x = x + y
    h = LL.rmsnorm(x, p["ln2"])
    if cfg.moe:
        y, aux = LL.moe(p["mlp"], cfg, h)
    else:
        y = LL.mlp(p["mlp"], h)
    return x + y, new_cache, aux


def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    if kind == "ssd":
        return SSD.init_ssd_cache(cfg, batch, dtype)
    if kind == "rglru":
        return RG.init_rglru_cache(cfg, batch, dtype)
    if cfg.mla:
        return LL.init_mla_cache(cfg, batch, max_len, dtype)
    # local-window layers never need more than `window` cache entries
    eff = min(max_len, cfg.window) if cfg.window else max_len
    return LL.init_attention_cache(cfg, batch, eff, dtype)


# ---------------------------------------------------------------------------
# whole-model init / forward
# ---------------------------------------------------------------------------


def _pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "hybrid" and cfg.pattern:
        return tuple(cfg.pattern)
    return ("ssd",) if cfg.family == "ssm" else ("attn",)


def _layout(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    pat = _pattern(cfg)
    n_units = cfg.n_layers // len(pat)
    tail = tuple(pat[i] for i in range(cfg.n_layers - n_units * len(pat)))
    return pat, n_units, tail


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    pat, n_units, tail = _layout(cfg)
    keys = jax.random.split(key, 4)
    params: Params = {
        "embed": LL._dense_init(keys[0], (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = LL._dense_init(keys[1], (cfg.d_model, cfg.vocab), dt)

    def stack_init(kind, pos):
        ks = jax.random.split(jax.random.fold_in(keys[2], pos), n_units)
        return jax.vmap(lambda k: _init_layer(k, cfg, kind))(ks)

    if n_units:
        params["scan"] = {str(i): stack_init(kind, i) for i, kind in enumerate(pat)}
    for j, kind in enumerate(tail):
        params[f"tail_{j}"] = _init_layer(jax.random.fold_in(keys[3], j), cfg, kind)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.compute_dtype)
    pat, n_units, tail = _layout(cfg)
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if n_units:
        cache["scan"] = {
            str(i): jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape),
                _init_layer_cache(cfg, kind, batch, max_len, dt),
            )
            for i, kind in enumerate(pat)
        }
    for j, kind in enumerate(tail):
        cache[f"tail_{j}"] = _init_layer_cache(cfg, kind, batch, max_len, dt)
    return cache


def forward(
    cfg: ModelConfig,
    params: Params,
    inputs: jax.Array,
    *,
    mode: str = "train",
    cache: Optional[Dict[str, Any]] = None,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """inputs: int tokens (B,S) or float embeddings (B,S,D) (modality stubs).
    Returns (logits (B,S,V) float32, new_cache, moe_aux_loss)."""
    from ..distributed.sharding import constrain_activation

    pat, n_units, tail = _layout(cfg)
    if jnp.issubdtype(inputs.dtype, jnp.integer):
        x = jnp.take(params["embed"], inputs, axis=0)
    else:
        x = inputs.astype(jnp.dtype(cfg.compute_dtype))
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    x = constrain_activation(x)
    B, S = x.shape[:2]
    if positions is None:
        if mode == "decode":
            assert cache is not None
            positions = jnp.broadcast_to(cache["pos"], (B, S))
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {} if (mode != "train" and cache is not None) or mode == "prefill" else None
    if mode == "prefill" and cache is None:
        cache = init_cache(cfg, B, S)
        new_cache = {}

    def unit_body(x, unit_params, unit_cache):
        """One pattern unit (len(pat) layers). Returns (x, new_unit_cache, aux)."""
        aux = jnp.zeros((), jnp.float32)
        ncache = {}
        for i, kind in enumerate(pat):
            c = unit_cache.get(str(i)) if unit_cache else None
            x, nc, a = _apply_layer(unit_params[str(i)], cfg, kind, x, positions, mode, c)
            sp = {1: "model"} if (cfg.seq_parallel and mode == "train") else None
            x = constrain_activation(x, extra=sp)
            aux += a
            if nc is not None:
                ncache[str(i)] = nc
        return x, ncache, aux

    if cfg.remat and mode == "train":
        if cfg.remat_policy == "residual":
            # Save ONLY the named bf16 residual stream between units. The
            # dots-saveable policy stacks f32 matmul outputs across the layer
            # scan — 2 x (L, B, S, D) f32 buffers that dominated the memory
            # roofline term (qwen3 hillclimb, EXPERIMENTS §Perf iter 2b).
            from jax.ad_checkpoint import checkpoint_name

            inner_body = unit_body

            def named_body(x, unit_params, unit_cache):
                x, ncache, aux = inner_body(x, unit_params, unit_cache)
                return checkpoint_name(x, "residual"), ncache, aux

            unit_body = jax.checkpoint(
                named_body,
                policy=jax.checkpoint_policies.save_only_these_names("residual"),
            )
        else:  # "dots" — the paper-faithful baseline policy
            unit_body = jax.checkpoint(
                unit_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )

    if n_units:
        scan_params = params["scan"]
        scan_cache = cache.get("scan") if cache else None

        if scan_cache is not None:
            def scan_fn(carry, xs):
                x, aux = carry
                up, uc = xs
                x, ncache, a = unit_body(x, up, uc)
                return (x, aux + a), ncache

            (x, aux_total), ncaches = jax.lax.scan(
                scan_fn, (x, aux_total), (scan_params, scan_cache)
            )
        else:
            def scan_fn_nc(carry, up):
                x, aux = carry
                x, ncache, a = unit_body(x, up, None)
                return (x, aux + a), ncache

            (x, aux_total), ncaches = jax.lax.scan(scan_fn_nc, (x, aux_total), scan_params)
        if new_cache is not None and ncaches:
            new_cache["scan"] = ncaches

    for j, kind in enumerate(tail):
        c = cache.get(f"tail_{j}") if cache else None
        x, nc, a = _apply_layer(params[f"tail_{j}"], cfg, kind, x, positions, mode, c)
        aux_total += a
        if new_cache is not None and nc is not None:
            new_cache[f"tail_{j}"] = nc

    x = LL.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=jnp.float32)
    logits = constrain_activation(logits, extra={-1: "model"})
    if new_cache is not None:
        base_pos = cache["pos"] if (cache is not None and mode == "decode") else 0
        new_cache["pos"] = base_pos + (1 if mode == "decode" else S)
    return logits, new_cache, aux_total


# ---------------------------------------------------------------------------
# the probabilistic program (paper technique — first-class feature)
# ---------------------------------------------------------------------------


def lm_program(cfg: ModelConfig, params_template: Optional[Params] = None):
    """Build the generative program  p(tokens | params):
        params ~ `param` sites (via `module`)      [or lifted priors]
        for b in plate(batch):  obs_t ~ Categorical(logits_t)
    Training this with SVI + no latents == maximum likelihood; the ELBO is
    exactly the negative token cross-entropy plus the MoE aux loss (through a
    `factor` site), so the PPL path and the hand-written path share HLO.
    """
    from ..core import primitives as P
    from ..distributions import Categorical

    def program(batch: Dict[str, jax.Array]):
        template = params_template
        if template is None:
            template = init_params(cfg, jax.random.PRNGKey(0))
        params = P.module("lm", template)
        inputs = batch.get("inputs", batch.get("tokens"))
        targets = batch["targets"]
        logits, _, aux = forward(cfg, params, inputs, mode="train")
        if cfg.moe:
            P.factor("moe_aux", -cfg.router_aux_weight * aux)
        with P.plate("batch", targets.shape[0], dim=-2):
            with P.plate("time", targets.shape[1], dim=-1):
                if cfg.use_pallas:
                    from ..kernels.ops import categorical_logprob

                    P.factor("obs", categorical_logprob(logits, targets))
                else:
                    P.sample("obs", Categorical(logits=logits), obs=targets)
        return logits

    return program


# ---------------------------------------------------------------------------
# step builders (pure, jit/pjit-able)
# ---------------------------------------------------------------------------


def nll_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
    """Hand-written baseline loss (the Fig-3 'raw framework' comparator)."""
    inputs = batch.get("inputs", batch.get("tokens"))
    logits, _, aux = forward(cfg, params, inputs, mode="train")
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_lp = jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
    loss = -jnp.mean(tok_lp)
    if cfg.moe:
        loss = loss + cfg.router_aux_weight * aux / batch["targets"].size
    return loss


def make_train_step(cfg: ModelConfig, optimizer):
    """(opt_state, batch) -> (opt_state, metrics): MLE via the PPL path.
    The ELBO of `lm_program` with an empty guide is -sum log p(obs) — we use
    the mean-per-token scaling to match `nll_loss` exactly."""

    def loss_fn(params, batch):
        return nll_loss(cfg, params, batch)

    def train_step(opt_state, batch):
        params = optimizer.get_params(opt_state)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        opt_state = optimizer.update(grads, opt_state)
        return opt_state, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params: Params, tokens_or_embeds: jax.Array):
        logits, cache, _ = forward(cfg, params, tokens_or_embeds, mode="prefill")
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params: Params, cache: Dict[str, Any], token: jax.Array, rng: jax.Array):
        """token: (B, 1) int32 (or (B,1,D) embeds). Greedy+sampled logits."""
        logits, cache, _ = forward(cfg, params, token, mode="decode", cache=cache)
        next_token = jax.random.categorical(rng, logits[:, -1])
        return next_token, cache, logits[:, -1]

    return decode_step
