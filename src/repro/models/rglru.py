"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate, block-diagonal)
    i_t = sigmoid(W_x x_t + b_x)          (input gate, block-diagonal)
    a_t = exp(-c * softplus(Lambda) * r_t)        with c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear in h, so the full sequence runs as a parallel
`jax.lax.associative_scan` (log-depth on TPU) — this is the hardware
adaptation of the paper's custom linear-scan GPU kernel. Decode mode is the
plain one-step recurrence with a constant-size state, which is why
recurrentgemma supports the long_500k shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense_init
from .ssm import _causal_conv

Params = Dict[str, Any]

_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    w = cfg.lru_width or D
    blocks = max(cfg.n_heads, 1)
    bw = w // blocks
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # Lambda init so that a in [0.9, 0.999] at r=1 (paper appendix)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / (2 * _C)) - 1.0)  # softplus^-1
    return {
        "wx_in": _dense_init(ks[1], (D, w), dt),  # x branch input proj
        "wy_in": _dense_init(ks[2], (D, w), dt),  # gated (gelu) branch
        "conv_w": _dense_init(ks[3], (cfg.conv_width, w), dt, scale=0.5),
        "wa": _dense_init(ks[4], (blocks, bw, bw), jnp.float32),  # block-diag
        "wxg": _dense_init(ks[5], (blocks, bw, bw), jnp.float32),
        "ba": jnp.zeros((w,), jnp.float32),
        "bxg": jnp.zeros((w,), jnp.float32),
        "Lambda": lam,
        "out_proj": _dense_init(jax.random.fold_in(key, 7), (w, D), dt),
    }


def _block_diag_proj(x, w_blocks, bias):
    """x: (B,S,W) -> block-diagonal linear. w_blocks: (blocks, bw, bw)."""
    B, S, W = x.shape
    nb, bw, _ = w_blocks.shape
    xb = x.reshape(B, S, nb, bw)
    out = jnp.einsum("bsnw,nwv->bsnv", xb.astype(jnp.float32), w_blocks)
    return out.reshape(B, S, W) + bias


def rglru_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None) -> jax.Array:
    """Solve h_t = a_t h_{t-1} + b_t along axis 1 via associative scan.
    a, b: (B, S, W) float32."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    mode: str = "train",
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full recurrent block: (conv -> RG-LRU) * gelu-gate -> out_proj.
    decode cache: {'h': (B,W) f32, 'conv': (B,width-1,W)}."""
    B, S, D = x.shape

    xs = jnp.einsum("bsd,dw->bsw", x, p["wx_in"], preferred_element_type=jnp.float32).astype(x.dtype)
    ys = jnp.einsum("bsd,dw->bsw", x, p["wy_in"], preferred_element_type=jnp.float32)
    gate = jax.nn.gelu(ys)  # float32

    if mode == "decode":
        assert cache is not None and S == 1
        conv_buf = jnp.concatenate([cache["conv"], xs], axis=1)
        xc = jnp.sum(conv_buf * p["conv_w"][None], axis=1, keepdims=True).astype(jnp.float32)
        new_conv = conv_buf[:, 1:]
    else:
        xc = _causal_conv(xs, p["conv_w"]).astype(jnp.float32)
        new_conv = (
            jnp.pad(xs, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))[:, -(cfg.conv_width - 1):]
            if mode == "prefill"
            else None
        )

    r = jax.nn.sigmoid(_block_diag_proj(xc.astype(x.dtype), p["wa"], p["ba"]))
    i = jax.nn.sigmoid(_block_diag_proj(xc.astype(x.dtype), p["wxg"], p["bxg"]))
    log_a = -_C * jax.nn.softplus(p["Lambda"])[None, None, :] * r  # (B,S,W) <= 0
    a = jnp.exp(log_a)
    gated_x = i * xc
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    if mode == "decode":
        h = a[:, 0] * cache["h"] + b[:, 0]  # (B,W)
        new_cache = {"h": h, "conv": new_conv}
        hseq = h[:, None]
    else:
        h0 = cache["h"] if (cache is not None and "h" in cache) else None
        hseq = rglru_scan(a, b, h0)
        new_cache = {"h": hseq[:, -1], "conv": new_conv} if mode == "prefill" else None

    out = hseq * gate
    out = jnp.einsum("bsw,wd->bsd", out.astype(x.dtype), p["out_proj"],
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype), new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }
