"""Model configuration — one dataclass covers all 10 assigned families
(dense / MoE / MLA / SSM / hybrid / audio / vlm backbones)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0  # 0 -> attention-free (ssm)
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: Optional[int] = None  # default d_model // n_heads
    attn_bias: bool = False  # qwen1.5 QKV bias
    qk_norm: bool = False  # qwen3 per-head RMSNorm on q,k
    rope_theta: float = 10000.0
    window: Optional[int] = None  # local attention window (recurrentgemma)
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: Optional[int] = None  # fine-grained expert ffn dim (deepseek)
    moe_impl: str = "einsum"  # einsum (GShard dispatch, baseline) | sort (optimized)
    moe_group: int = 512  # GShard token-group size (dispatch is O(T*k*cf*group))
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # MLA (deepseek-v2)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4
    # hybrid (recurrentgemma): layer pattern, e.g. ("rglru","rglru","attn")
    pattern: Tuple[str, ...] = ()
    lru_width: Optional[int] = None
    # modality frontend stub
    modality: str = "text"  # text | audio | vlm
    # numerics / implementation
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    use_pallas: bool = False  # flip on for real-TPU flash attention / logprob
    remat: bool = True  # activation checkpointing per layer
    seq_parallel: bool = False  # shard the residual stream's S dim on 'model'
    # (sequence parallelism: turns TP activation all-reduces into
    # reduce-scatter + all-gather pairs; §Perf hillclimb)
    remat_policy: str = "residual"  # residual (save bf16 stream only) | dots (baseline)
    attn_impl: str = "flash"  # flash (custom-vjp, recompute bwd) | blockwise (baseline)
    # probabilistic extras (the paper's technique as a feature)
    bayesian_last_layer: bool = False  # lift lm_head to a sampled latent

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (N for MODEL_FLOPS = 6·N·D roofline term) ---------
    def param_count(self, active_only: bool = False) -> int:
        D, V, L = self.d_model, self.vocab, self.n_layers
        total = V * D  # embed
        if not self.tie_embeddings:
            total += D * V
        total += D  # final norm
        hd = self.resolved_head_dim
        for i in range(L):
            kind = self.layer_kind(i)
            if kind == "ssd":
                di, ns = self.d_inner, self.ssm_state
                nh = self.ssm_nheads
                total += D * (2 * di + 2 * ns + nh)  # in_proj(z,x) + B,C + dt
                total += self.conv_width * (di + 2 * ns)
                total += nh * 3  # A_log, D skip, dt_bias
                total += di  # gated RMSNorm
                total += di * D  # out proj
                total += D  # norm
                continue
            if kind == "rglru":
                w = self.lru_width or self.d_model
                total += D * w * 2  # input + output-gate projections
                total += self.conv_width * w  # temporal conv
                blocks = max(self.n_heads, 1)
                total += 2 * w * (w // blocks)  # block-diagonal RG-LRU gates
                total += 3 * w  # Lambda + gate biases
                total += w * D  # out proj
                total += 2 * D  # two norms
                total += 3 * D * self.d_ff  # every Griffin layer has an MLP
                continue
            # attention
            n_kv = self.n_kv_heads or self.n_heads
            if self.mla:
                r = self.kv_lora_rank
                qd = self.qk_nope_dim + self.qk_rope_dim
                total += D * self.n_heads * qd  # q proj
                total += D * (r + self.qk_rope_dim)  # kv down
                total += r * self.n_heads * (self.qk_nope_dim + self.v_head_dim)  # kv up
                total += self.n_heads * self.v_head_dim * D  # o proj
            else:
                total += D * self.n_heads * hd + 2 * D * n_kv * hd + self.n_heads * hd * D
                if self.attn_bias:
                    total += (self.n_heads + 2 * n_kv) * hd
                if self.qk_norm:
                    total += 2 * hd
            total += 2 * D  # two norms
            # mlp / moe
            if self.moe:
                de = self.d_expert or self.d_ff
                routed = self.n_experts * 3 * D * de
                shared = self.n_shared_experts * 3 * D * de
                total += D * self.n_experts  # router
                if active_only:
                    total += self.top_k * 3 * D * de + shared
                else:
                    total += routed + shared
            else:
                total += 3 * D * self.d_ff  # SwiGLU: gate, up, down
        return total

    def layer_kind(self, i: int) -> str:
        if self.family == "ssm":
            return "ssd"
        if self.family == "hybrid" and self.pattern:
            return self.pattern[i % len(self.pattern)]
        return "attn"

    def n_layers_of(self, kind: str) -> int:
        return sum(1 for i in range(self.n_layers) if self.layer_kind(i) == kind)
