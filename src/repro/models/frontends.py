"""Modality frontend STUBS (per assignment spec: `[audio]`/`[vlm]` entries
specify the transformer BACKBONE only; the frontend supplies precomputed
frame/patch embeddings through `input_specs()`).

The stubs are deterministic, cheap, and shape-faithful:
* `AudioStub`  — musicgen: EnCodec frame tokens -> (B, S, D) embeddings.
* `VisionStub` — pixtral: image patches -> (B, S_img, D) prefix embeddings.

They exist so smoke tests can fabricate real arrays and so `input_specs`
can describe the dry-run inputs; a production system would swap in the real
EnCodec / ViT towers behind the same functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def audio_stub_embed(cfg: ModelConfig, frame_tokens: jax.Array) -> jax.Array:
    """frame_tokens: (B, S) int32 in [0, vocab) -> (B, S, D) embeddings.
    Deterministic sinusoidal code embedding (stands in for EnCodec frames +
    codebook embedding sum)."""
    B, S = frame_tokens.shape
    D = cfg.d_model
    freqs = jnp.exp(-jnp.arange(D, dtype=jnp.float32) / D)
    phase = frame_tokens[..., None].astype(jnp.float32) * freqs
    return (jnp.sin(phase) / (D ** 0.5)).astype(jnp.dtype(cfg.compute_dtype))


def vision_stub_embed(cfg: ModelConfig, patches: jax.Array) -> jax.Array:
    """patches: (B, P, patch_dim) float -> (B, P, D) via a fixed projection
    (stands in for the Pixtral ViT tower)."""
    B, P, pd = patches.shape
    D = cfg.d_model
    key = jax.random.PRNGKey(0)
    proj = jax.random.normal(key, (pd, D), jnp.float32) / (pd ** 0.5)
    return jnp.einsum("bpd,dk->bpk", patches.astype(jnp.float32), proj).astype(
        jnp.dtype(cfg.compute_dtype)
    )


def frontend_embed(cfg: ModelConfig, raw: jax.Array) -> jax.Array:
    if cfg.modality == "audio":
        return audio_stub_embed(cfg, raw)
    if cfg.modality == "vlm":
        return vision_stub_embed(cfg, raw)
    raise ValueError(f"no frontend for modality={cfg.modality}")
