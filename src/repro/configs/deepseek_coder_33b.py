"""DeepSeek-Coder 33B [arXiv:2401.14196; hf] — dense llama-arch.
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    rope_theta=100000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128, vocab=512,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
