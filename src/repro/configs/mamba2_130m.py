"""Mamba2-130M [arXiv:2405.21060; unverified] — attention-free SSD
(state-space duality). 24L d_model=768 vocab=50280, ssm_state=128,
headdim=64, expand=2 (d_inner=1536, 24 ssm heads). Supports long_500k
(constant-size recurrent state)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    conv_width=4,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, vocab=512, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
