"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B; hf] — dense with QKV bias.
24L d_model=1024 16H (kv=16, MHA) d_ff=2816 vocab=151936, tied embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    attn_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
