"""MusicGen-Large [arXiv:2306.05284; hf] — decoder-only transformer over
EnCodec audio tokens. 48L d_model=2048 32H (MHA) d_ff=8192 vocab=2048.
The EnCodec frontend is a STUB (frontends.audio_stub_embed): `input_specs`
feeds precomputed (B, S, D) frame embeddings; targets are codebook tokens."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    modality="audio",
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
