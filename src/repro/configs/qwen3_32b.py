"""Qwen3-32B [hf:Qwen/Qwen3-8B family; hf] — dense with per-head q/k RMSNorm.
64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, head_dim=128
(Qwen3 decouples head_dim from d_model/n_heads)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
