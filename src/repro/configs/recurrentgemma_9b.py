"""RecurrentGemma-9B [arXiv:2402.19427; unverified] — Griffin hybrid:
RG-LRU + local attention in a 2:1 pattern. 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000, window=2048, lru_width=4096. Supports long_500k
(bounded window + constant LRU state)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    window=2048,
    pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=512,
    window=8, lru_width=64,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
