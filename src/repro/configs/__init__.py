"""Architecture registry: the 10 assigned architectures, their input-shape
sets, and the (arch x shape) dry-run cell enumeration.

Shapes (per assignment):
    train_4k     seq 4,096   global_batch 256   -> train_step
    prefill_32k  seq 32,768  global_batch 32    -> prefill_step
    decode_32k   seq 32,768  global_batch 128   -> decode_step (1 new token)
    long_500k    seq 524,288 global_batch 1     -> decode_step; SSM/hybrid only
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "smollm-135m": "smollm_135m",
    "qwen1.5-0.5b": "qwen15_05b",
    "qwen3-32b": "qwen3_32b",
    "musicgen-large": "musicgen_large",
    "mamba2-130m": "mamba2_130m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "pixtral-12b": "pixtral_12b",
}

ARCHS: Tuple[str, ...] = tuple(_MODULES)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only constant-state (ssm) and
# bounded-window (hybrid) families run it; pure full-attention archs are
# recorded as SKIP (DESIGN.md §5).
_LONG_OK = ("mamba2-130m", "recurrentgemma-9b")


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch '{arch}'; choose from {list(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in _LONG_OK
    return True


def cells(include_skips: bool = False) -> List[Tuple[str, str]]:
    """All assigned (arch, shape) dry-run cells. 10 archs x 4 shapes = 40
    assigned cells; 8 long_500k cells are SKIP -> 32 runnable."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            if include_skips or shape_applicable(arch, shape):
                out.append((arch, shape))
    return out
