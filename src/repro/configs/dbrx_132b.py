"""DBRX 132B [hf:databricks/dbrx-base; unverified] — 16-expert top-4
fine-grained MoE. 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=True,
    n_experts=16,
    top_k=4,
    d_expert=10752,
    rope_theta=500000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=96, vocab=512,
    n_experts=4, top_k=2, d_expert=96,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
