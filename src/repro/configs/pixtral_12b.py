"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409; unverified] — VLM: pixtral
ViT frontend (STUB, frontends.vision_stub_embed) + Mistral-Nemo-style
decoder. 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072,
head_dim=128 (decoupled)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    modality="vlm",
    rope_theta=1000000000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
