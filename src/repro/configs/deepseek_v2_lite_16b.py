"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf] — MoE with Multi-head Latent
Attention. Assigned spec: 27L d_model=2048 16H d_ff=1408 vocab=102400,
MoE 64 routed top-6 + 2 shared, MLA kv_lora_rank=512.

Notes vs the HF checkpoint: Lite uses full-rank q (no q_lora); the real
checkpoint's first layer is a dense MLP — we keep all layers MoE so the
scan-over-layers stays homogeneous (parameter count difference ~0.2%,
recorded in DESIGN.md §5)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    moe=True,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_expert=1408,
    mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32, vocab=512,
    n_experts=8, top_k=2, n_shared_experts=1, d_expert=32,
    kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
    param_dtype="float32", compute_dtype="float32", remat=False,
)
